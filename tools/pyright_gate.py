"""Pyright error-count gate for the typed modules.

Runs ``pyright --outputjson`` over the scope in ``pyrightconfig.json``
(``src/repro/core`` + ``src/repro/analysis``, basic mode) and compares
per-file *error* counts against the committed ``pyright_baseline.json``.
The gate is a ratchet:

  * a file exceeding its baselined count fails CI (new type errors);
  * a file under its baselined count prints a nudge to re-baseline
    (``--write``), so the budget only ever shrinks;
  * warnings are reported but never gate (jax has no complete stubs).

Run locally (needs the pyright CLI on PATH — ``npm i -g pyright``)::

    python tools/pyright_gate.py            # gate
    python tools/pyright_gate.py --write    # accept current counts
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import subprocess
import sys
from typing import Dict

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE = os.path.join(ROOT, "pyright_baseline.json")


def run_pyright() -> dict:
    exe = shutil.which("pyright")
    if exe is None:
        print("pyright not on PATH (npm i -g pyright)", file=sys.stderr)
        raise SystemExit(2)
    proc = subprocess.run(
        [exe, "--outputjson", "--project",
         os.path.join(ROOT, "pyrightconfig.json")],
        capture_output=True, text=True, cwd=ROOT)
    try:
        return json.loads(proc.stdout)
    except ValueError:
        sys.stderr.write(proc.stdout + proc.stderr)
        raise SystemExit(2)


def error_counts(report: dict) -> Dict[str, int]:
    counts: Dict[str, int] = {}
    for diag in report.get("generalDiagnostics", []):
        if diag.get("severity") != "error":
            continue
        rel = os.path.relpath(diag.get("file", "?"), ROOT).replace(
            os.sep, "/")
        counts[rel] = counts.get(rel, 0) + 1
    return counts


def load_baseline() -> Dict[str, int]:
    try:
        with open(BASELINE, encoding="utf-8") as f:
            return dict(json.load(f).get("files", {}))
    except (OSError, ValueError):
        return {}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--write", action="store_true",
                    help="accept current per-file error counts as baseline")
    args = ap.parse_args(argv)

    report = run_pyright()
    counts = error_counts(report)
    summary = report.get("summary", {})

    if args.write:
        payload = {"files": {k: counts[k] for k in sorted(counts)}}
        with open(BASELINE, "w", encoding="utf-8") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {BASELINE}: {sum(counts.values())} error(s) in "
              f"{len(counts)} file(s)")
        return 0

    baseline = load_baseline()
    failed = False
    for path in sorted(set(counts) | set(baseline)):
        have, allowed = counts.get(path, 0), baseline.get(path, 0)
        if have > allowed:
            print(f"FAIL {path}: {have} error(s), baseline allows {allowed}")
            failed = True
        elif have < allowed:
            print(f"note {path}: {have} error(s) < baseline {allowed} — "
                  f"ratchet down with `python tools/pyright_gate.py --write`")
    print(f"pyright: {summary.get('errorCount', '?')} error(s), "
          f"{summary.get('warningCount', '?')} warning(s) over "
          f"{summary.get('filesAnalyzed', '?')} file(s); "
          f"baseline {'FAILED' if failed else 'ok'}")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
