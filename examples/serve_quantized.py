"""Serve a small model with batched requests and packed-int4 weights — the
paper's deployment scenario (dense arrays of 4-bit multipliers for edge
inference).  Compares W4A4-packed against bf16 serving on the same prompts.

    PYTHONPATH=src python examples/serve_quantized.py
"""

import json

from repro.launch.serve import serve


def main():
    common = dict(reduced=True, batch=4, prompt_len=32, gen=16)
    for quant in ("float", "w4a16_packed", "w4a4_packed"):
        out = serve("qwen2-0.5b", quant_backend=quant, **common)
        print(f"{quant:14s} prefill={out['prefill_s']*1e3:7.1f} ms "
              f"decode={out['decode_tok_per_s']:6.1f} tok/s")
    # int8 KV cache on top of packed weights (decode memory-term lever)
    out = serve("qwen2-0.5b", quant_backend="w4a4_packed",
                cache_dtype="int8", **common)
    print(f"{'w4a4+int8kv':14s} prefill={out['prefill_s']*1e3:7.1f} ms "
          f"decode={out['decode_tok_per_s']:6.1f} tok/s")
    print("serving OK (greedy tokens):",
          json.dumps(out["generated"][0][:6]))


if __name__ == "__main__":
    main()
