"""Serve a small model under continuous batching with packed-int4 weights —
the paper's deployment scenario (dense arrays of 4-bit multipliers for edge
inference).  Compares W4A4-packed against bf16 serving on the same Poisson
request trace, then stacks the int8 KV cache on top (decode memory-term
lever).

    PYTHONPATH=src python examples/serve_quantized.py
"""

from repro.launch.serve import serve


def main():
    common = dict(reduced=True, layout="paged", max_batch=4, requests=6,
                  rate=0.5, prompt_lens=(8, 16), gen_lens=(8,),
                  page_size=8, num_pages=48, max_ctx=64)
    for quant in ("float", "w4a16_packed", "w4a4_packed"):
        out = serve("qwen2-0.5b", quant_backend=quant, **common)
        print(f"{quant:14s} decode={out['tokens_per_s']:6.1f} tok/s "
              f"p50={out['latency_p50_s']*1e3:7.1f} ms "
              f"p95={out['latency_p95_s']*1e3:7.1f} ms")
    out = serve("qwen2-0.5b", quant_backend="w4a4_packed",
                cache_dtype="int8", **common)
    print(f"{'w4a4+int8kv':14s} decode={out['tokens_per_s']:6.1f} tok/s "
          f"p50={out['latency_p50_s']*1e3:7.1f} ms")
    # paged vs contiguous KV must agree bit-for-bit on the same trace
    out = serve("qwen2-0.5b", quant_backend="w4a4_packed",
                **{**common, "layout": "compare"})
    print("serving OK; paged == contiguous:", out["bit_identical"])


if __name__ == "__main__":
    main()
