"""Serve a small model under mixed-precision quantization plans and from
quantized checkpoints — the paper's deployment scenario (dense arrays of
4-bit multipliers for edge inference), deployed the way real systems do it:
sensitive sites (lm_head, block 0 attention) keep higher precision while
the bulk runs W4.

Three acts:
  1. uniform plans: bf16 vs weight-only int4 vs full W4A4 on one trace;
  2. mixed plans: the `w4a16_sensitive_fp` / `mixed_sensitive` presets and
     an inline plan string, via `--quant-plan` semantics;
  3. quantized checkpoints: save packed nibbles + scales + plan, restore
     with no float master, and verify the restored tree serves bit-identical
     logits/tokens vs the same plan applied to float masters.

    PYTHONPATH=src python examples/serve_quantized.py
"""

from repro.launch.serve import serve


def show(tag, out):
    print(f"{tag:22s} decode={out['tokens_per_s']:6.1f} tok/s "
          f"p50={out['latency_p50_s']*1e3:7.1f} ms "
          f"p95={out['latency_p95_s']*1e3:7.1f} ms")


def main():
    common = dict(reduced=True, layers=2, layout="paged", max_batch=4,
                  requests=6, rate=0.5, prompt_lens=(8, 16), gen_lens=(8,),
                  page_size=8, num_pages=48, max_ctx=64)

    # -- 1. uniform plans (the legacy backend strings map onto these) -------
    for plan in ("*=float", "*=w4a16_packed;lm_head=float", "serve_w4a4"):
        show(plan, serve("qwen2-0.5b", quant_plan=plan, **common))

    # -- 2. mixed plans: presets and an inline rule string ------------------
    for plan in ("w4a16_sensitive_fp", "mixed_sensitive",
                 "block[0].*=float;ffn.*=w4a16;*=int_sim;lm_head=float"):
        show(plan[:22], serve("qwen2-0.5b", quant_plan=plan, **common))

    # -- 3. quantized checkpoint: save -> restore -> serve, verified --------
    out = serve("qwen2-0.5b", quant_plan="mixed_sensitive",
                quantized_ckpt=True, **common)
    q = out["quantized_ckpt"]
    show("from quantized ckpt", out)
    print(f"checkpoint: {q['quantized_bytes']/1e3:.0f} kB packed vs "
          f"{q['float_master_bytes']/1e3:.0f} kB float masters, "
          f"load {q['load_s']*1e3:.0f} ms")
    print("bit-identical logits vs plan-on-masters:",
          q["bit_identical_logits"], "| generated tokens match:",
          q["tokens_match"])
    assert q["bit_identical_logits"] and q["tokens_match"]


if __name__ == "__main__":
    main()
