"""Quickstart: the paper's 4-bit multiplier, from gate level to GEMM.

Runs in seconds on CPU:
  1. simulate the exact 11-LUT/2-CARRY4 netlist and verify all 256 products;
  2. compare area/delay against the prior designs (paper Tables II/III);
  3. multiply int4 tensors with the TPU LUT kernel (paper's mechanism on VMEM);
  4. run a quantized GEMM through the int4 MXU path and the bit-exact
     netlist oracle, and check they agree.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    analyze, build_lm_mult4, build_proposed_mult4, resources,
)
from repro.core.qlinear import QuantConfig, qdense
from repro.kernels import ops


def main():
    # 1. the paper's circuit, bit-exact ------------------------------------
    netlist = build_proposed_mult4()
    a = jnp.arange(16, dtype=jnp.uint8)[:, None] * jnp.ones((1, 16), jnp.uint8)
    b = jnp.arange(16, dtype=jnp.uint8)[None, :] * jnp.ones((16, 1), jnp.uint8)
    products = netlist(a, b, mode="init")         # evaluate INIT truth tables
    assert (products == (a * b).astype(jnp.uint8)).all()
    print("[1] proposed netlist: all 256 products exact (INIT-table mode)")
    print(f"    LUT1 INIT = 0x{netlist.init_table()['LUT1']:016X} "
          "(matches paper Table I)")

    # 2. area / delay vs the prior design ----------------------------------
    for nl in (netlist, build_lm_mult4()):
        r, t = resources(nl), analyze(nl)
        print(f"[2] {nl.name:9s} LUTs={r['luts']:2d} CARRY4={r['carry4']} "
              f"CPD={t['cpd']:.3f} ns (logic {t['logic']:.3f} / net {t['net']:.3f})")

    # 3. Pallas LUT kernel (the mechanism on TPU VMEM) ----------------------
    rng = np.random.default_rng(0)
    qa = jnp.asarray(rng.integers(-8, 8, (4, 64), np.int8))
    qb = jnp.asarray(rng.integers(-8, 8, (4, 64), np.int8))
    prod = ops.mul4(qa, qb)                       # interpret mode on CPU
    assert (prod.astype(jnp.int32) == qa.astype(jnp.int32) * qb).all()
    print("[3] Pallas lut_mul4 kernel: exact on random int4 tensors")

    # 4. quantized GEMM: MXU path vs the circuit oracle ---------------------
    w = jnp.asarray(rng.standard_normal((32, 16), np.float32)) * 0.1
    x = jnp.asarray(rng.standard_normal((4, 32), np.float32))
    y_int = qdense(w, x, QuantConfig(backend="int_sim"))
    y_net = qdense(w, x, QuantConfig(backend="netlist"))
    np.testing.assert_allclose(np.asarray(y_int), np.asarray(y_net), rtol=1e-6)
    print("[4] W4A4 GEMM: int8-MXU path == gate-level netlist oracle")
    print("quickstart OK")


if __name__ == "__main__":
    main()
