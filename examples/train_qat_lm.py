"""End-to-end driver: train a ~100M-parameter LM with W4A4 QAT (the paper's
technique as the training-time feature) for a few hundred steps on CPU.

Uses a scaled-down qwen2-family config (~100M params with the full vocab),
the synthetic data pipeline, AdamW + warmup-cosine, checkpoint/resume and
the step watchdog — i.e. the same trainer the dry-run lowers at 512 devices.

    PYTHONPATH=src python examples/train_qat_lm.py [--steps 300]
"""

import argparse
import dataclasses
import logging

import jax

from repro.configs import get_config
from repro.launch.train import train
from repro.configs.base import ArchConfig


def hundred_m_config() -> ArchConfig:
    """~100M-param dense config (qwen2 family, shrunk depth/width)."""
    base = get_config("qwen2-0.5b")
    return dataclasses.replace(
        base, name="qwen2-100m", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=2, head_dim=64, d_ff=2048, vocab=32000,
    )


def main():
    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_qat_100m")
    args = ap.parse_args()

    from repro.models import init_model

    cfg = hundred_m_config()
    n_params = sum(
        x.size for x in jax.tree.leaves(
            jax.eval_shape(lambda: init_model(jax.random.PRNGKey(0), cfg))))
    print(f"params: {n_params/1e6:.1f}M (QAT backend: fake_quant W4A4)")

    # register the custom config so the trainer can find it
    from repro.configs import REGISTRY
    REGISTRY[cfg.name] = cfg
    _, history = train(
        cfg.name, steps=args.steps, batch=args.batch, seq=args.seq,
        reduced=False, ckpt_dir=args.ckpt, save_every=100,
        quant_backend="fake_quant",
    )
    print(f"loss: {history[0]:.3f} -> {history[-1]:.3f} "
          f"over {len(history)} steps")
    assert history[-1] < history[0], "loss should decrease"


if __name__ == "__main__":
    main()
