"""Property tests on system invariants (hypothesis): implementation knobs
(chunk sizes, attention impl, scan vs unroll) must never change the math."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs import Runtime, get_config
from repro.models import init_model
from repro.models.transformer import forward


def _logits_for(cfg, rt, params, toks):
    h, _, _ = forward(params, toks, cfg, rt, return_hidden=True)
    return np.asarray(h, np.float32)


@given(st.sampled_from([4, 8, 16, 32]))
@settings(max_examples=4, deadline=None)
def test_ssd_chunk_size_invariance(chunk):
    """Mamba-2 SSD output must not depend on the chunk length."""
    cfg = get_config("mamba2-130m").reduced()
    cfg_c = dataclasses.replace(cfg, ssm_chunk=chunk)
    cfg_ref = dataclasses.replace(cfg, ssm_chunk=32)   # single chunk (S=32)
    rt = Runtime(loss_chunk=0, compute_dtype="float32", quant_backend="float")
    params = init_model(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    np.testing.assert_allclose(
        _logits_for(cfg_c, rt, params, toks),
        _logits_for(cfg_ref, rt, params, toks),
        rtol=2e-4, atol=2e-5,
    )


@given(st.sampled_from([4, 8, 12, 64]))
@settings(max_examples=4, deadline=None)
def test_attention_chunk_invariance(chunk_q):
    """Chunked attention == full attention for any query-chunk size."""
    cfg = get_config("qwen3-4b").reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab)
    rt_full = Runtime(attn_impl="full", loss_chunk=0,
                      compute_dtype="float32", quant_backend="float")
    rt_chunk = Runtime(attn_impl="chunked", attn_chunk_q=chunk_q,
                       loss_chunk=0, compute_dtype="float32",
                       quant_backend="float")
    np.testing.assert_allclose(
        _logits_for(cfg, rt_chunk, params, toks),
        _logits_for(cfg, rt_full, params, toks),
        rtol=1e-5, atol=1e-6,
    )


@given(st.sampled_from(["musicgen-large", "recurrentgemma-9b"]))
@settings(max_examples=2, deadline=None)
def test_window_mask_only_limits_past(arch):
    """A local window >= S equals global attention; < S changes outputs."""
    cfg = get_config(arch).reduced()
    if not cfg.local_window:
        cfg = dataclasses.replace(cfg, local_window=16)
    params = init_model(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 24), 0, cfg.vocab)
    rt = Runtime(loss_chunk=0, compute_dtype="float32", quant_backend="float")
    big = dataclasses.replace(cfg, local_window=1024)
    none = dataclasses.replace(cfg, local_window=0)
    np.testing.assert_allclose(
        _logits_for(big, rt, params, toks),
        _logits_for(none, rt, params, toks),
        rtol=1e-5, atol=1e-6,
    )
