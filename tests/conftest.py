"""Test-env shims.

The container may lack `hypothesis`; the property tests only use a small,
well-defined slice of its API (given/settings + sampled_from / integers /
floats / tuples / lists / .map).  When the real package is missing we register a
deterministic mini-implementation under the same module name so the
properties still execute with seeded example streams instead of being
skipped wholesale.
"""

from __future__ import annotations

import sys
import types

import numpy as np


def _install_hypothesis_shim():
    class _Strategy:
        """Deterministic example stream; `draw(rng)` yields one example."""

        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)))

    def sampled_from(options):
        opts = list(options)
        state = {"i": 0}

        def draw(rng):  # cycle => full coverage when max_examples >= len
            v = opts[state["i"] % len(opts)]
            state["i"] += 1
            return v

        return _Strategy(draw)

    def integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    def floats(min_value=-1e9, max_value=1e9, allow_nan=None, width=64):
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)))

    def tuples(*strategies):
        return _Strategy(lambda rng: tuple(s.draw(rng) for s in strategies))

    def lists(elements, min_size=0, max_size=16):
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.draw(rng) for _ in range(n)]

        return _Strategy(draw)

    def settings(max_examples=20, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*strategies):
        def deco(fn):
            n_examples = getattr(fn, "_max_examples", 20)

            def runner():
                rng = np.random.default_rng(1234)
                for _ in range(n_examples):
                    fn(*[s.draw(rng) for s in strategies])

            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            return runner

        return deco

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    strategies = types.ModuleType("hypothesis.strategies")
    strategies.sampled_from = sampled_from
    strategies.integers = integers
    strategies.floats = floats
    strategies.tuples = tuples
    strategies.lists = lists
    mod.strategies = strategies
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies


try:
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_shim()
