"""Per-kernel validation: shape/dtype sweeps + allclose against ref.py oracles
(interpret mode executes the kernel bodies on CPU; `interpret=None` rows also
check the ops-level dispatch, which routes to the XLA twins off-TPU)."""

import gc

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.quant import group_quantize, pack_int4, unpack_int4
from repro.kernels import ops, packing, ref


RNG = np.random.default_rng(1234)


def rand_int4(shape):
    return jnp.asarray(RNG.integers(-8, 8, size=shape, dtype=np.int8))


# ---------------------------------------------------------------- lut_mul4 --
@pytest.mark.parametrize("shape", [(16,), (5, 33), (2, 3, 130), (1, 1, 1, 257)])
@pytest.mark.parametrize("strategy", ["onehot", "take"])
def test_lut_mul4_sweep(shape, strategy):
    a, b = rand_int4(shape), rand_int4(shape)
    got = ops.mul4(a, b, strategy=strategy, interpret=True)
    exp = ref.mul4_ref(a, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


def test_lut_mul4_exhaustive_all_pairs():
    """All 256 signed int4 pairs through the Pallas LUT kernel (paper §V)."""
    vals = np.arange(-8, 8, dtype=np.int8)
    a = jnp.asarray(np.repeat(vals, 16))
    b = jnp.asarray(np.tile(vals, 16))
    got = ops.mul4(a, b, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(got), (np.repeat(vals, 16).astype(np.int32)
                          * np.tile(vals, 16).astype(np.int32)).astype(np.int8)
    )


def test_lut_kernel_matches_fpga_netlist():
    """Cross-validate the TPU LUT kernel against the bit-exact FPGA netlist."""
    from repro.core import build_proposed_mult4
    from repro.core.quant import to_unsigned_mag

    nl = build_proposed_mult4()
    q_a, q_b = rand_int4((64,)), rand_int4((64,))
    mag_a, sign_a = to_unsigned_mag(q_a)
    mag_b, sign_b = to_unsigned_mag(q_b)
    netlist_prod = nl(mag_a, mag_b).astype(jnp.int32) * sign_a * sign_b
    kernel_prod = ops.mul4(q_a, q_b, interpret=True).astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(netlist_prod), np.asarray(kernel_prod))


# ------------------------------------------------------------- int4_matmul --
def _int4_case(M, K, N):
    aq = rand_int4((M, K))
    a_scale = jnp.asarray(RNG.random((M, 1), dtype=np.float32) + 0.05)
    wq = rand_int4((K, N if N % 2 == 0 else N + 1))
    w_scale = jnp.asarray(RNG.random((1, wq.shape[1]), dtype=np.float32) + 0.05)
    return aq, a_scale, pack_int4(wq, axis=-1), w_scale


@pytest.mark.parametrize(
    "M,K,N", [(8, 64, 16), (128, 128, 128), (200, 384, 250), (1, 512, 1024)]
)
def test_int4_matmul_sweep(M, K, N):
    aq, a_scale, wp, w_scale = _int4_case(M, K, N)
    got = ops.int4_matmul(aq, a_scale, wp, w_scale, interpret=True,
                          bm=128, bn=128, bk=128)
    exp = ref.int4_matmul_ref(aq, a_scale, wp, w_scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("M,K,N", [(33, 70, 50), (7, 71, 130), (5, 9, 24)])
@pytest.mark.parametrize("blocks", [{}, dict(bm=32, bn=64, bk=64),
                                    dict(bm=8, bn=128, bk=256)])
def test_int4_matmul_odd_shapes_nondefault_blocks(M, K, N, blocks):
    """Odd (unpadded) M/K/N — including odd K, which the planar layout pads
    to even — across non-default tile shapes."""
    aq, a_scale, wp, w_scale = _int4_case(M, K, N)
    got = ops.int4_matmul(aq, a_scale, wp, w_scale, interpret=True, **blocks)
    exp = ref.int4_matmul_ref(aq, a_scale, wp, w_scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), rtol=1e-6, atol=1e-6)


def test_int4_matmul_integer_core_is_exact():
    """With unit scales the kernel must be bit-exact integer arithmetic."""
    M = K = N = 128
    aq, wq = rand_int4((M, K)), rand_int4((K, N))
    ones_m, ones_n = jnp.ones((M, 1), jnp.float32), jnp.ones((1, N), jnp.float32)
    got = ops.int4_matmul(aq, ones_m, pack_int4(wq, -1), ones_n, interpret=True)
    exp = jnp.dot(aq.astype(jnp.int32), wq.astype(jnp.int32))
    np.testing.assert_array_equal(np.asarray(got).astype(np.int64),
                                  np.asarray(exp).astype(np.int64))


@pytest.mark.parametrize("M,K,N", [(5, 64, 48), (33, 70, 50), (1, 256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_int4_matmul_fused_quantize(M, K, N, dtype):
    """The in-kernel activation quantize must match quantize-then-matmul.

    Exact .5 ties in x/scale may round one LSB apart between the fused
    kernel and the eager oracle (fast-math reciprocal across the tie — see
    _quantize_tile); each flipped tie moves an output element by at most
    |w| <= 8 weight counts, so rows with ties get a correspondingly wider
    (still tight) bound while tie-free rows must agree to float noise."""
    x = jnp.asarray(RNG.standard_normal((M, K)).astype(np.float32)).astype(dtype)
    wq = rand_int4((K, N + N % 2))
    w_scale = jnp.asarray(RNG.random((1, wq.shape[1]), dtype=np.float32) + 0.05)
    wp = pack_int4(wq, axis=-1)
    got = np.asarray(ops.int4_matmul_fused(x, wp, w_scale, interpret=True))
    exp = np.asarray(ref.int4_matmul_fused_ref(x, wp, w_scale))

    x32 = np.asarray(x, np.float32)
    a_scale = np.maximum(np.abs(x32).max(axis=1, keepdims=True), 1e-8) / 7.0
    ratio = x32 / a_scale
    ties = (np.abs(ratio - np.round(ratio)) == 0.5).sum(axis=1)   # per row
    tol = np.abs(exp) * 1e-5 + 1e-5 \
        + (ties * 8.0 * a_scale[:, 0] * float(w_scale.max()))[:, None]
    assert (np.abs(got - exp) <= tol).all(), \
        f"max err {np.abs(got - exp).max()} vs tol {tol.min()}"


# ------------------------------------------------------------ w4a16_matmul --
@pytest.mark.parametrize("M,K,N,G", [(32, 256, 64, 64), (100, 512, 130, 128),
                                     (1, 1024, 256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_w4a16_sweep(M, K, N, G, dtype):
    w = jnp.asarray(RNG.standard_normal((K, N + N % 2)).astype(np.float32))
    qg, sg = group_quantize(w, G)
    wp = pack_int4(qg, axis=-1)
    x = jnp.asarray(RNG.standard_normal((M, K)).astype(np.float32)).astype(dtype)
    got = ops.w4a16_matmul(x, wp, sg, G, interpret=True, bm=128, bn=128, bk=128)
    exp = ref.w4a16_matmul_ref(x, wp, sg, G)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), rtol=tol, atol=tol)


@pytest.mark.parametrize("M,K,N", [(9, 130, 50), (1, 77, 24)])
@pytest.mark.parametrize("blocks", [{}, dict(bm=16, bn=32, bk=64)])
def test_w4a16_per_channel_odd_shapes(M, K, N, blocks):
    """group_size >= K collapses to per-channel 2D scales (the epilogue-only
    kernel); odd K exercises the planar padding."""
    w = jnp.asarray(RNG.standard_normal((K, N + N % 2)).astype(np.float32))
    qg, sg = group_quantize(w, K)            # per-channel: scale [1, N]
    assert sg.ndim == 2
    wp = pack_int4(qg, axis=-1)
    x = jnp.asarray(RNG.standard_normal((M, K)).astype(np.float32))
    got = ops.w4a16_matmul(x, wp, sg, K, interpret=True, **blocks)
    exp = ref.w4a16_matmul_ref(x, wp, sg, K)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), rtol=1e-4, atol=1e-4)


def test_w4a16_grouped_odd_group_count():
    """K = 3 groups: the planar halves can't split the groups evenly, so the
    repack pads K to a 2*G multiple; results must still match the oracle."""
    M, K, N, G = (16, 192, 32, 64)
    w = jnp.asarray(RNG.standard_normal((K, N)).astype(np.float32))
    qg, sg = group_quantize(w, G)
    assert sg.shape[0] == 3
    wp = pack_int4(qg, axis=-1)
    x = jnp.asarray(RNG.standard_normal((M, K)).astype(np.float32))
    for blocks in ({}, dict(bm=16, bn=32, bk=128)):
        got = ops.w4a16_matmul(x, wp, sg, G, interpret=True, **blocks)
        exp = ref.w4a16_matmul_ref(x, wp, sg, G)
        np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("G", [64, 0])
def test_w4a16_both_group_size_paths_nondefault_blocks(G):
    """Grouped [K/G,1,N] vs per-channel [1,N] scale paths, same weights."""
    M, K, N = 24, 256, 96
    w = jnp.asarray(RNG.standard_normal((K, N)).astype(np.float32))
    g = G if G else K
    qg, sg = group_quantize(w, g)
    assert sg.ndim == (3 if G else 2)
    wp = pack_int4(qg, axis=-1)
    x = jnp.asarray(RNG.standard_normal((M, K)).astype(np.float32))
    got = ops.w4a16_matmul(x, wp, sg, g, interpret=True, bm=32, bn=32, bk=256)
    exp = ref.w4a16_matmul_ref(x, wp, sg, g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------- ops dispatch --
def test_ops_dispatch_xla_twin_matches_kernels(monkeypatch):
    """Off-TPU, interpret=None dispatches to the XLA twins — same math as
    the interpreted kernels, full XLA speed (the serving path on CPU)."""
    import jax

    if jax.default_backend() == "tpu":
        pytest.skip("dispatch test targets non-TPU hosts")
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    assert not ops.use_pallas()
    aq, a_scale, wp, w_scale = _int4_case(16, 64, 32)
    np.testing.assert_allclose(
        np.asarray(ops.int4_matmul(aq, a_scale, wp, w_scale)),
        np.asarray(ops.int4_matmul(aq, a_scale, wp, w_scale, interpret=True)),
        rtol=1e-6)
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert ops.use_pallas()


# --------------------------------------------------------------- packing ----
@pytest.mark.parametrize("axis", [0, 1, -1])
def test_pack_roundtrip(axis):
    q = rand_int4((48, 64))
    np.testing.assert_array_equal(
        np.asarray(unpack_int4(pack_int4(q, axis), axis)), np.asarray(q)
    )


@pytest.mark.parametrize("K", [48, 37])
def test_kmajor_roundtrip(K):
    q = rand_int4((K, 32))
    km = packing.pack_kmajor(q)
    assert km.shape == ((K + 1) // 2, 32) and km.dtype == jnp.uint8
    back = packing.unpack_kmajor(km)[:K]
    np.testing.assert_array_equal(np.asarray(back), np.asarray(q))


def test_kmajor_row_mult_alignment():
    q = rand_int4((96, 16))
    km = packing.pack_kmajor(q, row_mult=64)           # K -> 128, halves of 64
    assert km.shape == (64, 16)
    back = packing.unpack_kmajor(km)
    np.testing.assert_array_equal(np.asarray(back[:96]), np.asarray(q))
    assert not np.asarray(back[96:]).any()             # zero int4 padding


def test_nmajor_to_kmajor_matches_direct_pack():
    q = rand_int4((64, 48))
    np.testing.assert_array_equal(
        np.asarray(packing.nmajor_to_kmajor(pack_int4(q, -1))),
        np.asarray(packing.pack_kmajor(q)))


def test_prepack_cache_hits_and_weakref_eviction():
    packing.clear_prepack_cache()
    wp = pack_int4(rand_int4((64, 48)), -1)
    first = packing.prepack_kmajor(wp)
    assert packing.prepack_kmajor(wp) is first         # cache hit
    assert packing.prepack_cache_size() == 1
    del wp
    gc.collect()
    assert packing.prepack_cache_size() == 0           # weakref eviction
