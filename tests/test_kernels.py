"""Per-kernel validation: shape/dtype sweeps + allclose against ref.py oracles
(interpret mode executes the kernel bodies on CPU)."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.quant import group_quantize, pack_int4, unpack_int4
from repro.kernels import ops, ref


RNG = np.random.default_rng(1234)


def rand_int4(shape):
    return jnp.asarray(RNG.integers(-8, 8, size=shape, dtype=np.int8))


# ---------------------------------------------------------------- lut_mul4 --
@pytest.mark.parametrize("shape", [(16,), (5, 33), (2, 3, 130), (1, 1, 1, 257)])
@pytest.mark.parametrize("strategy", ["onehot", "take"])
def test_lut_mul4_sweep(shape, strategy):
    a, b = rand_int4(shape), rand_int4(shape)
    got = ops.mul4(a, b, strategy=strategy)
    exp = ref.mul4_ref(a, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


def test_lut_mul4_exhaustive_all_pairs():
    """All 256 signed int4 pairs through the Pallas LUT kernel (paper §V)."""
    vals = np.arange(-8, 8, dtype=np.int8)
    a = jnp.asarray(np.repeat(vals, 16))
    b = jnp.asarray(np.tile(vals, 16))
    got = ops.mul4(a, b)
    np.testing.assert_array_equal(
        np.asarray(got), (np.repeat(vals, 16).astype(np.int32)
                          * np.tile(vals, 16).astype(np.int32)).astype(np.int8)
    )


def test_lut_kernel_matches_fpga_netlist():
    """Cross-validate the TPU LUT kernel against the bit-exact FPGA netlist."""
    from repro.core import build_proposed_mult4
    from repro.core.quant import to_unsigned_mag

    nl = build_proposed_mult4()
    q_a, q_b = rand_int4((64,)), rand_int4((64,))
    mag_a, sign_a = to_unsigned_mag(q_a)
    mag_b, sign_b = to_unsigned_mag(q_b)
    netlist_prod = nl(mag_a, mag_b).astype(jnp.int32) * sign_a * sign_b
    kernel_prod = ops.mul4(q_a, q_b).astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(netlist_prod), np.asarray(kernel_prod))


# ------------------------------------------------------------- int4_matmul --
@pytest.mark.parametrize(
    "M,K,N", [(8, 64, 16), (128, 128, 128), (200, 384, 250), (1, 512, 1024)]
)
def test_int4_matmul_sweep(M, K, N):
    aq = rand_int4((M, K))
    a_scale = jnp.asarray(RNG.random((M, 1), dtype=np.float32) + 0.05)
    wq = rand_int4((K, N if N % 2 == 0 else N + 1))
    w_scale = jnp.asarray(RNG.random((1, wq.shape[1]), dtype=np.float32) + 0.05)
    wp = pack_int4(wq, axis=-1)
    got = ops.int4_matmul(aq, a_scale, wp, w_scale, bm=128, bn=128, bk=128)
    exp = ref.int4_matmul_ref(aq, a_scale, wp, w_scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), rtol=1e-6, atol=1e-6)


def test_int4_matmul_integer_core_is_exact():
    """With unit scales the kernel must be bit-exact integer arithmetic."""
    M = K = N = 128
    aq, wq = rand_int4((M, K)), rand_int4((K, N))
    ones_m, ones_n = jnp.ones((M, 1), jnp.float32), jnp.ones((1, N), jnp.float32)
    got = ops.int4_matmul(aq, ones_m, pack_int4(wq, -1), ones_n)
    exp = jnp.dot(aq.astype(jnp.int32), wq.astype(jnp.int32))
    np.testing.assert_array_equal(np.asarray(got).astype(np.int64),
                                  np.asarray(exp).astype(np.int64))


# ------------------------------------------------------------ w4a16_matmul --
@pytest.mark.parametrize("M,K,N,G", [(32, 256, 64, 64), (100, 512, 130, 128),
                                     (1, 1024, 256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_w4a16_sweep(M, K, N, G, dtype):
    w = jnp.asarray(RNG.standard_normal((K, N + N % 2)).astype(np.float32))
    qg, sg = group_quantize(w, G)
    wp = pack_int4(qg, axis=-1)
    x = jnp.asarray(RNG.standard_normal((M, K)).astype(np.float32)).astype(dtype)
    got = ops.w4a16_matmul(x, wp, sg, G, bm=128, bn=128, bk=128)
    exp = ref.w4a16_matmul_ref(x, wp, sg, G)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), rtol=tol, atol=tol)


# --------------------------------------------------------------- packing ----
@pytest.mark.parametrize("axis", [0, 1, -1])
def test_pack_roundtrip(axis):
    q = rand_int4((48, 64))
    np.testing.assert_array_equal(
        np.asarray(unpack_int4(pack_int4(q, axis), axis)), np.asarray(q)
    )
