"""Fused paged-attention kernels: parity with the gather-then-attend
reference across page sizes, ragged contexts, cache dtypes, and window
masking; flash prefill vs the dense core; engine-level bit-exactness of the
fused path including a preempt->resume trace; autotune attn tags."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import Runtime, ServingConfig, get_config
from repro.kernels import autotune, ops
from repro.kernels import paged_attention as pa
from repro.models.attention import attention_core, quantize_kv
from repro.serving.api import poisson_trace, run_trace
from repro.serving.engine import InferenceEngine, build_params
from repro.serving.kv_pages import paged_read


def _pool_setup(rng, B, KV, hd, ps, pps, cache_dtype="bfloat16"):
    """Random pool + permuted block tables (pages deliberately scattered)."""
    P = B * pps + 4
    k32 = jnp.asarray(rng.standard_normal((P, ps, KV, hd)), jnp.float32)
    v32 = jnp.asarray(rng.standard_normal((P, ps, KV, hd)), jnp.float32)
    tbl = jnp.asarray(rng.permutation(P)[: B * pps].reshape(B, pps),
                      jnp.int32)
    if cache_dtype in ("int8", "int4"):
        kq, ks = quantize_kv(k32, cache_dtype == "int4")
        vq, vs = quantize_kv(v32, cache_dtype == "int4")
        return {"tbl": tbl, "k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
    dt = jnp.bfloat16 if cache_dtype == "bfloat16" else jnp.float32
    return {"tbl": tbl, "k": k32.astype(dt), "v": v32.astype(dt)}


def _reference(q, cache, last, window=0):
    """The gather path: paged_read + dense attention (masked softmax)."""
    kf, vf, kpos = paged_read(cache, last)
    return attention_core(
        q[:, None], kf, vf, q_positions=last[:, None], k_positions=kpos,
        window=window, impl="full", chunk_q=64)[:, 0]


# ----------------------------------------------------------- decode parity --
@pytest.mark.parametrize("ps,pps", [(1, 16), (4, 8), (16, 2)])
def test_decode_xla_twin_bit_identical_across_page_sizes(ps, pps):
    """The XLA twin (what CPU serving executes) must be *bit-identical* to
    the gather reference for ragged per-row contexts, page-partial
    positions, and inactive rows."""
    rng = np.random.default_rng(ps)
    B, KV, G, hd = 4, 4, 2, 16
    cache = _pool_setup(rng, B, KV, hd, ps, pps)
    q = jnp.asarray(rng.standard_normal((B, KV * G, hd)), jnp.bfloat16)
    # ragged: mid-page, page-boundary, full, inactive
    last = jnp.asarray([ps * pps // 2 - 1, ps - 1, ps * pps - 1, -1],
                       jnp.int32)
    ref = _reference(q, cache, last)
    out = pa.paged_decode_attention_xla(q, cache["k"], cache["v"],
                                        cache["tbl"], last, pp=3)
    act = np.asarray(last) >= 0
    np.testing.assert_array_equal(np.float32(out)[act], np.float32(ref)[act])
    # inactive rows are masked to zero (finite, never NaN)
    assert not np.isnan(np.float32(out)).any()
    assert (np.float32(out)[~act] == 0).all()


@pytest.mark.parametrize("cache_dtype", ["bfloat16", "int8", "int4"])
def test_decode_xla_twin_quantized_pools(cache_dtype):
    rng = np.random.default_rng(7)
    B, KV, G, hd, ps, pps = 3, 4, 2, 16, 4, 6
    cache = _pool_setup(rng, B, KV, hd, ps, pps, cache_dtype)
    q = jnp.asarray(rng.standard_normal((B, KV * G, hd)), jnp.bfloat16)
    last = jnp.asarray([ps * pps - 1, 5, 0], jnp.int32)
    ref = _reference(q, cache, last)
    out = pa.paged_decode_attention_xla(
        q, cache["k"], cache["v"], cache["tbl"], last,
        cache.get("k_scale"), cache.get("v_scale"), pp=2)
    np.testing.assert_array_equal(np.float32(out), np.float32(ref))


@pytest.mark.parametrize("cache_dtype", ["bfloat16", "int8"])
def test_decode_pallas_kernel_matches_reference(cache_dtype):
    """The Pallas kernel (interpret mode) runs single-pass online softmax:
    tolerance parity with the dense reference, inactive rows masked."""
    rng = np.random.default_rng(11)
    B, KV, G, hd, ps, pps = 3, 4, 2, 16, 4, 6
    cache = _pool_setup(rng, B, KV, hd, ps, pps, cache_dtype)
    q = jnp.asarray(rng.standard_normal((B, KV * G, hd)), jnp.bfloat16)
    last = jnp.asarray([ps * pps - 1, 9, -1], jnp.int32)
    ref = _reference(q, cache, last)
    for pp, bkv in [(1, 0), (4, 2)]:
        out = pa.paged_decode_attention(
            q, cache["k"], cache["v"], cache["tbl"], last,
            cache.get("k_scale"), cache.get("v_scale"),
            pp=pp, bkv=bkv, interpret=True)
        act = np.asarray(last) >= 0
        np.testing.assert_allclose(np.float32(out)[act],
                                   np.float32(ref)[act], atol=2e-2)
        assert (np.float32(out)[~act] == 0).all()


def test_decode_window_masking():
    rng = np.random.default_rng(13)
    B, KV, G, hd, ps, pps = 2, 2, 2, 16, 4, 8
    cache = _pool_setup(rng, B, KV, hd, ps, pps)
    q = jnp.asarray(rng.standard_normal((B, KV * G, hd)), jnp.bfloat16)
    last = jnp.asarray([ps * pps - 1, 11], jnp.int32)
    for window in (5, 16):
        ref = _reference(q, cache, last, window=window)
        tw = pa.paged_decode_attention_xla(
            q, cache["k"], cache["v"], cache["tbl"], last,
            window=window, pp=2)
        np.testing.assert_array_equal(np.float32(tw), np.float32(ref))
        kr = pa.paged_decode_attention(
            q, cache["k"], cache["v"], cache["tbl"], last,
            window=window, pp=2, interpret=True)
        np.testing.assert_allclose(np.float32(kr), np.float32(tw), atol=2e-2)


def test_ops_dispatch_routes_xla_twin_off_tpu():
    """interpret=None off-TPU must take the XLA twin (never the slow
    interpreter) and agree with the explicit twin call bitwise."""
    rng = np.random.default_rng(17)
    B, KV, G, hd, ps, pps = 2, 2, 2, 16, 4, 4
    cache = _pool_setup(rng, B, KV, hd, ps, pps)
    q = jnp.asarray(rng.standard_normal((B, KV * G, hd)), jnp.bfloat16)
    last = jnp.asarray([7, 14], jnp.int32)
    via_ops = ops.paged_decode_attention(q, cache["k"], cache["v"],
                                         cache["tbl"], last)
    blocks = autotune.get_blocks("attn.paged_decode", B, ps * pps,
                                 KV * G * hd, "bfloat16", group_size=ps)
    direct = pa.paged_decode_attention_xla(
        q, cache["k"], cache["v"], cache["tbl"], last,
        pp=max(1, blocks["bk"] // ps))
    np.testing.assert_array_equal(np.float32(via_ops), np.float32(direct))


# ---------------------------------------------------------- flash prefill --
def test_flash_prefill_matches_dense_core():
    rng = np.random.default_rng(19)
    B, KV, G, hd, S = 2, 2, 2, 16, 24
    H = KV * G
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.bfloat16)
    pos = np.broadcast_to(np.arange(S, dtype=np.int32), (B, S)).copy()
    pos[1, :5] = -1                                # left-pad row
    pos = jnp.asarray(pos)
    for window in (0, 7):
        ref = attention_core(q, k, v, q_positions=pos, k_positions=pos,
                             window=window, impl="full", chunk_q=64)
        tw = pa.flash_prefill_xla(q, k, v, pos, pos, window=window, bk=8)
        kr = pa.flash_prefill(q, k, v, pos, pos, window=window,
                              bq=8, bk=8, bkv=1, interpret=True)
        valid = np.asarray(pos) >= 0
        np.testing.assert_allclose(np.float32(tw)[valid],
                                   np.float32(ref)[valid], atol=2e-2)
        np.testing.assert_allclose(np.float32(kr)[valid],
                                   np.float32(tw)[valid], atol=2e-2)


def test_flash_impl_dispatches_from_attention_core():
    """attention_core(impl='flash') routes through kernels.ops and agrees
    with the chunked production path."""
    rng = np.random.default_rng(23)
    B, KV, G, hd, S = 2, 2, 2, 16, 16
    q = jnp.asarray(rng.standard_normal((B, S, KV * G, hd)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.bfloat16)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    ref = attention_core(q, k, v, q_positions=pos, k_positions=pos,
                         window=0, impl="chunked", chunk_q=8)
    out = attention_core(q, k, v, q_positions=pos, k_positions=pos,
                         window=0, impl="flash", chunk_q=8)
    np.testing.assert_allclose(np.float32(out), np.float32(ref), atol=2e-2)


# ------------------------------------------------------------ engine e2e ---
@pytest.fixture(scope="module")
def reduced_cfg():
    return get_config("qwen2-0.5b").reduced()


def _engine(cfg, rt, num_pages=32, page_size=8, max_ctx=32, params=None):
    sv = ServingConfig(layout="paged", max_batch=2, page_size=page_size,
                       num_pages=num_pages, max_ctx=max_ctx)
    return InferenceEngine(cfg, rt, sv, params=params, seed=0)


def test_engine_fused_vs_gather_bit_identical(reduced_cfg):
    import dataclasses
    rt = Runtime(quant_backend="float", cache_dtype="bfloat16", remat="none",
                 loss_chunk=0)
    params = build_params(reduced_cfg, rt)
    trace = poisson_trace(4, 1.0, [8], [6], reduced_cfg.vocab, seed=5)
    _, fin_f = run_trace(_engine(reduced_cfg, rt, params=params), trace)
    rt_g = dataclasses.replace(rt, paged_attn="gather")
    _, fin_g = run_trace(_engine(reduced_cfg, rt_g, params=params), trace)
    assert [r.tokens for r in fin_f] == [r.tokens for r in fin_g]


def test_engine_fused_preempt_resume_matches_gather(reduced_cfg):
    """A pool small enough to force preemption: the fused engine's
    recompute-resume trace must produce exactly the gather engine's
    tokens (and an unconstrained fused run's)."""
    import dataclasses
    rt = Runtime(quant_backend="float", cache_dtype="bfloat16", remat="none",
                 loss_chunk=0)
    params = build_params(reduced_cfg, rt)
    trace = poisson_trace(4, 2.0, [8], [8], reduced_cfg.vocab, seed=9)
    eng = _engine(reduced_cfg, rt, num_pages=6, page_size=4, max_ctx=16,
                  params=params)
    stats, fin = run_trace(eng, trace)
    assert stats["requests_preempted"] >= 1
    assert stats["paged_attn"] == "fused"
    rt_g = dataclasses.replace(rt, paged_attn="gather")
    _, fin_g = run_trace(
        _engine(reduced_cfg, rt_g, num_pages=6, page_size=4, max_ctx=16,
                params=params), trace)
    _, fin_big = run_trace(
        _engine(reduced_cfg, rt, num_pages=32, page_size=4, max_ctx=16,
                params=params), trace)
    assert [r.tokens for r in fin] == [r.tokens for r in fin_g]
    assert [r.tokens for r in fin] == [r.tokens for r in fin_big]


def test_engine_profile_reports_attn_split(reduced_cfg):
    rt = Runtime(quant_backend="float", cache_dtype="bfloat16", remat="none",
                 loss_chunk=0)
    eng = _engine(reduced_cfg, rt)
    trace = poisson_trace(2, 1.0, [8], [4], reduced_cfg.vocab, seed=1)
    run_trace(eng, trace)
    prof = eng.profile(reps=1)
    stats = eng.stats()
    assert stats["profile"] is prof
    assert prof["attn_us"] > 0 and prof["decode_step_us"] > 0
    assert prof["gemm_other_us"] == pytest.approx(
        max(prof["decode_step_us"] - prof["attn_us"], 0.0), abs=0.2)


# --------------------------------------------------------------- autotune --
def test_attn_autotune_tags():
    """attn.* ops get attention-shaped defaults, constraint-clean
    candidates, and cached entries round-trip through get_blocks."""
    b = autotune.get_blocks("attn.paged_decode", 4, 256, 1024, "bfloat16",
                            group_size=16)
    assert b["bk"] % 16 == 0 and b["bk"] <= 256
    cands = autotune.attn_candidate_blocks("attn.paged_decode", 4, 256, 1024,
                                           group_size=16)
    assert cands and all(c["bk"] % 16 == 0 for c in cands)
    b = autotune.get_blocks("attn.prefill", 64, 64, 1024, "bfloat16")
    assert b["bm"] <= 64 and b["bk"] <= 64

    autotune.reset()
    calls = []

    def make_call(blocks):
        calls.append(blocks)
        return lambda: None

    best, _ = autotune.tune(
        "attn.paged_decode", make_call, 4, 256, 1024, "bfloat16",
        group_size=16, timer=lambda fn: 1.0, save=False)
    assert best in calls
    hit = autotune.get_blocks("attn.paged_decode", 4, 256, 1024, "bfloat16",
                              group_size=16)
    assert hit == best
    autotune.reset()
