"""Ragged token-major serving step: kernel twin parity, token-budget
planner, engine bit-identity vs the bucketed step under batch-composition
churn, budget growth (a compile, never a steady-state recompile), and the
packing-waste telemetry both step modes share."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import Runtime, ServingConfig, get_config
from repro.kernels.ragged_attention import (
    ragged_attention_xla,
    ragged_decode_attention,
)
from repro.models.attention import quantize_kv
from repro.serving.api import bursty_trace, mixed_trace, run_trace
from repro.serving.engine import InferenceEngine
from repro.serving.kv_pages import PagedKVCacheManager
from repro.serving.scheduler import Request, Scheduler


# ------------------------------------------------- kernel vs XLA twin -----
def _pool(rng, P, ps, KV, hd, dtype):
    vals = rng.standard_normal((P, ps, KV, hd)).astype(np.float32)
    if dtype == "bfloat16":
        return jnp.asarray(vals, jnp.bfloat16), None
    q, s = quantize_kv(jnp.asarray(vals), int4=(dtype == "int4"))
    return q, s


@pytest.mark.parametrize("ps", [1, 4, 16])
@pytest.mark.parametrize("cache_dtype", ["bfloat16", "int8", "int4"])
def test_ragged_kernel_matches_xla_twin(ps, cache_dtype):
    """The Pallas ragged kernel (interpret mode off-TPU) and its pure-XLA
    twin agree on every packed row and emit exact zeros on padding rows,
    across page sizes and pool dtypes."""
    rng = np.random.default_rng(seed=ps * 7 + len(cache_dtype))
    P, KV, G, hd = 8, 2, 2, 8
    H = KV * G
    pps = 3                                        # pages per sequence
    maxB = 3
    k_pool, k_scale = _pool(rng, P, ps, KV, hd, cache_dtype)
    v_pool, v_scale = _pool(rng, P, ps, KV, hd, cache_dtype)
    # distinct physical pages per table row; row 2 left at the sentinel P
    # (a dead slot) so clamped fetches must mask to zero contribution
    tbl = np.full((maxB, pps), P, np.int32)
    perm = rng.permutation(P)[: 2 * pps].reshape(2, pps)
    tbl[:2] = perm
    # packed rows: two live slots at assorted positions + interior padding
    token_slot = np.asarray([0, 1, -1, 0, 1, -1], np.int32)
    max_pos = pps * ps - 1
    token_pos = np.asarray(
        [0, max_pos, -1, max_pos // 2, max_pos // 3, -1], np.int32)
    T = token_slot.shape[0]
    q = jnp.asarray(rng.standard_normal((T, H, hd)), jnp.bfloat16)

    for pp in (1, 2):
        out_k = ragged_decode_attention(
            q, k_pool, v_pool, jnp.asarray(tbl), jnp.asarray(token_slot),
            jnp.asarray(token_pos), k_scale, v_scale, pp=pp, interpret=True)
        out_x = ragged_attention_xla(
            q, k_pool, v_pool, jnp.asarray(tbl), jnp.asarray(token_slot),
            jnp.asarray(token_pos), k_scale, v_scale, pp=pp)
        a = np.asarray(out_k, np.float32)
        b = np.asarray(out_x, np.float32)
        assert np.max(np.abs(a - b)) < 2e-2, (ps, cache_dtype, pp)
        assert (a[token_slot < 0] == 0).all()
        assert (b[token_slot < 0] == 0).all()


# --------------------------------------------------- plan_tokens ----------
def _sched(max_batch=4, num_pages=32, page_size=4, max_ctx=32):
    sv = ServingConfig(layout="paged", max_batch=max_batch,
                       page_size=page_size, num_pages=num_pages,
                       max_ctx=max_ctx)
    return Scheduler(PagedKVCacheManager(sv), max_batch=max_batch)


def test_plan_tokens_decode_first_then_fifo_chunks():
    sched = _sched()
    for rid, L in enumerate((6, 10, 5)):
        sched.submit(Request(rid=rid, prompt=np.arange(L, dtype=np.int32),
                             max_new=4))
    sched.admit(now=0.0)
    # rid 0 already decoding (emitted once), rids 1-2 still in prefill
    r0 = sched.running[0]
    r0.n_cached, r0.decoding = 6, True
    r0.tokens.append(1)
    plan = sched.plan_tokens(8)
    # decode token first (slot order), then prefill chunks oldest-admit
    # first; rid 1 takes 7 of the remaining budget, rid 2 gets none
    assert [(r.rid, s, n) for r, s, n in plan] == [(0, 6, 1), (1, 0, 7)]
    # next step (after rid 1 cached those 7): rid 1 finishes its prefix,
    # leftover budget flows to rid 2
    sched.running[1].n_cached = 7
    plan = sched.plan_tokens(8)
    assert [(r.rid, s, n) for r, s, n in plan] == \
        [(0, 6, 1), (1, 7, 3), (2, 0, 4)]
    # a budget smaller than the decode set still plans only decode tokens
    r1, r2 = sched.running[1], sched.running[2]
    r1.n_cached, r1.decoding = 10, True
    r2.n_cached, r2.decoding = 5, True
    plan = sched.plan_tokens(2)
    assert [(r.rid, n) for r, _, n in plan] == [(0, 1), (1, 1)]


# ----------------------------------------- engine: ragged == bucketed -----
@functools.lru_cache(maxsize=1)
def _cfg():
    return get_config("qwen2-0.5b").reduced()


def _engines(cfg, *, cache_dtype, page_size, token_budget, num_pages=48):
    """(bucketed, ragged) engine pair over identical params/pool geometry.
    Lossy pools prefill over the cache on the bucketed side too — that is
    what the ragged step inherently does (write-then-attend), and the only
    configuration where per-token math can match bit-for-bit."""
    rt = Runtime(quant_backend="float", cache_dtype=cache_dtype,
                 remat="none", loss_chunk=0,
                 prefill_over_cache=(cache_dtype != "bfloat16"))
    mk = lambda step, tb: InferenceEngine(
        cfg, rt,
        ServingConfig(layout="paged", max_batch=4, page_size=page_size,
                      num_pages=num_pages, max_ctx=64, step=step,
                      token_budget=tb),
        seed=0)
    return mk("bucketed", 0), mk("ragged", token_budget)


@given(st.sampled_from([
    ("bfloat16", 4, 0, "mixed"),       # auto budget
    ("bfloat16", 1, 6, "bursty"),      # 1-token pages, tight budget
    ("bfloat16", 16, 8, "mixed"),
    ("int8", 4, 6, "bursty"),
    ("int4", 4, 9, "mixed"),
    ("bfloat16", 4, 5, "bursty"),      # odd budget, chunk boundaries shift
]), st.integers(0, 3))
@settings(max_examples=6, deadline=None)
def test_ragged_step_bit_identical_to_bucketed(spec, seed):
    """Property: under interleaved admissions, chunked prefills and decodes
    the ragged step emits exactly the bucketed engine's tokens — across
    page sizes {1,4,16}, bf16/int8/int4 pools, and budget choices that
    split prefixes at different chunk boundaries."""
    cache_dtype, ps, tb, kind = spec
    trace = (mixed_trace(6, [5, 9, 14], [3, 4], _cfg().vocab, seed=seed)
             if kind == "mixed" else
             bursty_trace(6, 3, 3, [5, 9, 14], [3, 4], _cfg().vocab,
                          seed=seed))
    num_pages = 96 if ps == 1 else 48
    eng_b, eng_r = _engines(_cfg(), cache_dtype=cache_dtype,
                            page_size=ps, token_budget=tb,
                            num_pages=num_pages)
    s_b, fin_b = run_trace(eng_b, trace)
    s_r, fin_r = run_trace(eng_r, trace)
    assert [r.tokens for r in fin_r] == [r.tokens for r in fin_b]
    assert s_r["recompiles"]["steady_state"] == 0
    # one compiled signature regardless of batch composition (no growth:
    # these budgets all cover max_batch)
    assert s_r["recompiles"]["by_fn"]["ragged"] == 1


def test_ragged_preemption_resume_bit_identical():
    """Pool pressure: both engines preempt and resume; tokens still match
    (recompute-style resume over a bf16 pool is lossless)."""
    trace = mixed_trace(5, [9, 14], [6], _cfg().vocab, seed=2)
    eng_b, eng_r = _engines(_cfg(), cache_dtype="bfloat16",
                            page_size=4, token_budget=8, num_pages=14)
    _, fin_b = run_trace(eng_b, trace)
    s_r, fin_r = run_trace(eng_r, trace)
    assert [r.tokens for r in fin_r] == [r.tokens for r in fin_b]
    assert s_r["requests_preempted"] >= 1
    assert s_r["recompiles"]["steady_state"] == 0


# ------------------------------------------------------ budget growth -----
def test_budget_growth_is_a_compile_not_a_recompile():
    """An explicit token_budget below max_batch doubles the step the decode
    set outgrows it: the budget metric bumps, the `compiles` count grows,
    steady_state stays zero, and tokens still match the bucketed run."""
    # short prompts + long generations: two requests decode simultaneously
    # while a third still prefills, so demand (2 decode + 1 chunk slot)
    # outgrows the budget of 2
    trace = mixed_trace(5, [3, 4], [6], _cfg().vocab, seed=1)
    eng_b, eng_r = _engines(_cfg(), cache_dtype="bfloat16",
                            page_size=4, token_budget=2)
    assert eng_r.stats()["token_budget"] == 2
    _, fin_b = run_trace(eng_b, trace)
    s_r, fin_r = run_trace(eng_r, trace)
    assert [r.tokens for r in fin_r] == [r.tokens for r in fin_b]
    grows = eng_r.metrics.counter("ragged_budget_grows_total").value
    assert grows >= 1
    assert s_r["token_budget"] >= 4                # 2 -> 4 at least once
    assert s_r["recompiles"]["by_fn"]["ragged"] == 1 + grows
    assert s_r["recompiles"]["steady_state"] == 0


# -------------------------------------------------- packing telemetry -----
def test_padding_waste_metrics_both_step_modes():
    """padding_tokens_wasted / token_utilization are live in both step
    modes: the ragged engine charges unused budget rows, the bucketed
    engine charges prefill-bucket and decode-bucket padding."""
    trace = mixed_trace(4, [5, 9], [3], _cfg().vocab, seed=0)
    eng_b, eng_r = _engines(_cfg(), cache_dtype="bfloat16",
                            page_size=4, token_budget=8)
    s_b, _ = run_trace(eng_b, trace)
    s_r, _ = run_trace(eng_r, trace)
    for s in (s_b, s_r):
        assert s["padding_tokens_wasted"] > 0       # 5/9 prompts never
        assert 0.0 < s["token_utilization"] <= 1.0  # align to buckets/budget
        assert s["padding_tokens_wasted"] == \
            eng_b.metrics.counter("padding_tokens_wasted_total").value \
            if s is s_b else True
    # accounting closes: packed + wasted == steps * capacity consumed
    assert eng_r.metrics.counter("padding_tokens_wasted_total").value == \
        s_r["padding_tokens_wasted"]
