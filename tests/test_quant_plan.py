"""Per-site QuantPlan: pattern matching, plan-aware forward, the backend
registry, and the quantized checkpoint format."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_quantized, save_quantized
from repro.configs import Runtime, ServingConfig, get_config
from repro.core import backends as qbackends
from repro.core import quant_plan as qp
from repro.core.qlinear import QuantConfig, qdense
from repro.core.quant_plan import (
    CKPT_PACKED,
    QuantPlan,
    active_plan,
    get_plan,
    plan_pack_tree,
    plan_repeat_uniform,
)
from repro.models import forward, init_model
from repro.models.common import rms_norm
from repro.models.transformer import apply_block

CFG = get_config("qwen2-0.5b").reduced(n_layers=2)
RT_KW = dict(scan_layers=True, attn_impl="chunked", attn_chunk_q=8,
             loss_chunk=0, remat="none")

#: non-uniform reference plan: w4a16 FFNs, float lm_head + block-0
#: attention, int_sim elsewhere (the acceptance plan)
MIXED = "mixed_sensitive"


def _params():
    return init_model(jax.random.PRNGKey(0), CFG)


def _tokens(batch=2, seq=16):
    return jax.random.randint(jax.random.PRNGKey(1), (batch, seq), 0,
                              CFG.vocab, dtype=jnp.int32)


def _tree_items(tree):
    return {
        tuple(str(getattr(k, "key", k)) for k in kp): leaf
        for kp, leaf in jax.tree_util.tree_leaves_with_path(tree)
    }


def assert_trees_bit_equal(a, b):
    fa, fb = _tree_items(a), _tree_items(b)
    assert fa.keys() == fb.keys()
    for k, la in fa.items():
        lb = fb[k]
        assert la.dtype == lb.dtype, (k, la.dtype, lb.dtype)
        assert np.array_equal(np.asarray(la), np.asarray(lb)), k


# ------------------------------------------------------------- matching ----
def test_pattern_precedence():
    A = QuantConfig(backend="float")
    B = QuantConfig(backend="int_sim")
    C = QuantConfig(backend="w4a16")
    plan = QuantPlan(rules=(
        ("*", A), ("attn.*", B), ("block[0].attn.qkv", C)))
    # block[0].attn.qkv beats attn.* beats *
    assert plan.resolve("block[0].attn.qkv") == C
    assert plan.resolve("block[1].attn.qkv") == B          # suffix glob
    assert plan.resolve("block[1].ffn.w_in") == A
    assert plan.resolve("lm_head") == A
    # brackets are literal, not character classes
    assert not qp.pattern_matches("block[0].*", "block0.attn.qkv")
    assert qp.pattern_matches("block[0].*", "block[0].attn.qkv")
    # block[0].* is more specific than ffn.*
    plan2 = QuantPlan(rules=(("ffn.*", B), ("block[0].*", A)))
    assert plan2.resolve("block[0].ffn.w_in") == A
    assert plan2.resolve("block[1].ffn.w_in") == B


def test_plan_specs_and_json_roundtrip(tmp_path):
    plan = get_plan("block[0].*=float;ffn.*=w4a16/g32;*=int_sim")
    assert plan.resolve("block[0].ffn.w_in").backend == "float"
    assert plan.resolve("block[1].ffn.w_in") == QuantConfig(
        backend="w4a16", group_size=32)
    assert plan.resolve("block[1].attn.qkv").backend == "int_sim"

    d = qp.plan_to_dict(plan)
    assert qp.plan_from_dict(d) == plan
    path = tmp_path / "plan.json"
    path.write_text(json.dumps(d))
    assert get_plan(str(path)) == plan

    for name in qp.PRESETS:                   # every preset resolves
        p = get_plan(name)
        assert p.resolve("block[3].ffn.w_in").backend
    with pytest.raises(ValueError):
        get_plan("not_a_preset_or_file_or_rules")

    # a typo'd plan with no catch-all fails loudly instead of silently
    # serving float everywhere
    with pytest.raises(ValueError, match="catch-all"):
        get_plan("ffn=w4a16").resolve("block[0].ffn.w_in")

    # editing a plan file in a long-lived process takes effect (mtime key)
    path2 = tmp_path / "plan2.json"
    path2.write_text(json.dumps(qp.plan_to_dict(get_plan("*=int_sim"))))
    assert get_plan(str(path2)).resolve("x").backend == "int_sim"
    path2.write_text(json.dumps(qp.plan_to_dict(get_plan("*=float"))))
    os.utime(path2, ns=(1, 987654321))  # force a distinct mtime regardless
    assert get_plan(str(path2)).resolve("x").backend == "float"


def test_runtime_override_routes_through_plan():
    # deprecated backend-string override keeps working (uniform plan) and
    # no longer loses the arch's bits/group settings
    arch = CFG
    rt = Runtime(quant_backend="w4a16")
    qc = rt.quant_cfg(arch)
    assert qc.backend == "w4a16" and qc.w_bits == arch.quant.w_bits
    assert rt.quant_cfg(arch, "lm_head").backend == "float"
    # plan override wins over the backend string and is per-site
    rt2 = Runtime(quant_plan=MIXED, quant_backend="float")
    assert rt2.quant_cfg(arch, "block[0].attn.qkv").backend == "float"
    assert rt2.quant_cfg(arch, "block[1].attn.qkv").backend == "int_sim"
    assert rt2.quant_cfg(arch, "block[1].ffn.w_in").backend == "w4a16"


def test_backend_registry_extension():
    w = jnp.asarray(np.random.default_rng(0).standard_normal((16, 8)),
                    jnp.float32)
    x = jnp.asarray(np.random.default_rng(1).standard_normal((4, 16)),
                    jnp.float32)

    @qbackends.register_backend("double_float")
    def _double(w_, x2, cfg, tag):
        return 2.0 * jnp.dot(x2, w_.astype(x2.dtype))

    try:
        y = qdense(w, x, QuantConfig(backend="double_float"))
        y_ref = qdense(w, x, QuantConfig(backend="float"))
        np.testing.assert_allclose(np.asarray(y), 2 * np.asarray(y_ref),
                                   rtol=1e-6)
    finally:
        del qbackends.BACKENDS["double_float"]
    with pytest.raises(ValueError, match="unknown quant backend"):
        qdense(w, x, QuantConfig(backend="no_such_backend"))


# ------------------------------------------------------------- forward ----
def test_uniform_plan_matches_legacy_backend():
    params, toks = _params(), _tokens()
    for backend in ("int_sim", "fake_quant"):
        rt_a = Runtime(quant_backend=backend, **RT_KW)
        rt_b = Runtime(quant_plan=f"*={backend};lm_head=float", **RT_KW)
        la = np.asarray(forward(params, toks, CFG, rt_a)[0], np.float32)
        lb = np.asarray(forward(params, toks, CFG, rt_b)[0], np.float32)
        assert np.array_equal(la, lb), backend


def test_mixed_plan_matches_manual_per_site_dispatch():
    """Forward under a per-layer plan == hand-rolled per-layer dispatch
    (layer 0 float, layer 1 int_sim) on a 2-layer model."""
    params, toks = _params(), _tokens()
    plan_spec = "block[0].*=float;*=int_sim;lm_head=float"
    rt_plan = Runtime(quant_plan=plan_spec, **RT_KW)
    assert not plan_repeat_uniform(active_plan(CFG, rt_plan), CFG)
    got = np.asarray(forward(params, toks, CFG, rt_plan)[0], np.float32)

    # manual reference: uniform-backend Runtime per layer
    B, S = toks.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = params["embed"]["tok"][toks].astype(jnp.bfloat16)
    layer_rts = [Runtime(quant_backend="float", **RT_KW),
                 Runtime(quant_backend="int_sim", **RT_KW)]
    for r, rt_r in enumerate(layer_rts):
        unit_p = jax.tree.map(lambda a: a[r], params["layers"])["u0"]
        x, _, _ = apply_block("A", unit_p, x, CFG, rt_r, positions)
    x = rms_norm(x, params["final_norm"], CFG.norm_eps)
    w = params["embed"]["tok"].astype(x.dtype)      # qwen2 ties embeddings
    ref = np.asarray(jnp.einsum("...d,vd->...v", x, w), np.float32)
    assert np.array_equal(got, ref)

    # scan-flag invariance: the non-uniform plan forces the unrolled loop
    rt_unroll = Runtime(quant_plan=plan_spec, **{**RT_KW,
                                                 "scan_layers": False})
    got2 = np.asarray(forward(params, toks, CFG, rt_unroll)[0], np.float32)
    assert np.array_equal(got, got2)


def test_grouped_w4a16_packing_keeps_group_numerics():
    """A w4a16/gN site packs with per-group scales, so a grouped plan keeps
    its numerics through a quantized checkpoint (packed == on-the-fly)."""
    import dataclasses

    from repro.core.qlinear import pack_weight_nd

    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((256, 64)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((4, 256)), jnp.float32)
    cfg = QuantConfig(backend="w4a16", group_size=128)
    packed = pack_weight_nd(w, cfg)
    assert packed["scale"].shape == (2, 1, 64)        # per-group, not [1, N]
    y_fly = np.asarray(qdense(w, x, cfg), np.float32)
    y_packed = np.asarray(
        qdense(packed, x, dataclasses.replace(cfg, backend="w4a16_packed")),
        np.float32)
    np.testing.assert_allclose(y_packed, y_fly, rtol=1e-6)
    # and a per-channel config still stores [1, N] scales
    assert pack_weight_nd(w, QuantConfig(backend="w4a16"))["scale"].shape \
        == (1, 64)


def test_group_size_must_divide_k_like_on_the_fly():
    """pack_weight_nd rejects non-dividing group sizes exactly like the
    on-the-fly group_quantize path — no silent per-channel fallback that
    would bake different numerics into a checkpoint than the plan names."""
    from repro.core.qlinear import pack_weight_nd

    w = jnp.ones((192, 16), jnp.float32)
    with pytest.raises(AssertionError):
        pack_weight_nd(w, QuantConfig(backend="w4a16", group_size=100))


def test_ckpt_experts_match_live_serving_semantics():
    """Expert stacks pack only for pre-packing backends: on-the-fly plans
    (int_sim) serve experts from float masters live, so the checkpoint
    must keep them float too."""
    from repro.configs import REGISTRY

    moe = next(c for c in sorted(REGISTRY.values(), key=lambda c: c.name)
               if c.n_experts).reduced()
    params = init_model(jax.random.PRNGKey(0), moe)
    for spec, packed_expected in (("*=int_sim;lm_head=float", False),
                                  ("serve_w4a4", True)):
        tree = plan_pack_tree(params, moe, get_plan(spec),
                              backends=CKPT_PACKED, min_size=1)
        blocks = (tree["layers"]["u0"] if "u0" in tree["layers"]
                  else tree["layers"]["r0"]["u0"])
        w_in = blocks["moe"]["experts"]["w_in"]
        assert isinstance(w_in, dict) == packed_expected, spec


def test_prepack_row_mult_covers_groups():
    """prepack_tree's planar K-major twin must round K up to whole scale
    groups (row_mult = 2G), for plain and layer-stacked weights alike;
    per-channel scales keep row_mult = 2."""
    from repro.core.qlinear import pack_weight_nd, prepack_tree

    rng = np.random.default_rng(2)
    w2 = jnp.asarray(rng.standard_normal((96, 16)), jnp.float32)
    w3 = jnp.asarray(rng.standard_normal((2, 96, 16)), jnp.float32)
    g32 = QuantConfig(backend="w4a16", group_size=32)
    tree = prepack_tree({
        "a": {"w_in": pack_weight_nd(w2, g32)},               # [3,1,16] scale
        "b": {"w_in": pack_weight_nd(w3, g32)},               # [2,3,1,16]
        "c": {"w_in": pack_weight_nd(w2, QuantConfig(backend="w4a16"))},
    })
    # K=96, G=32 -> K' rounded to 2G=64 -> 128 -> K'/2 = 64 planar rows
    assert tree["a"]["w_in"]["packed_km"].shape == (64, 16)
    assert tree["b"]["w_in"]["packed_km"].shape == (2, 64, 16)
    # per-channel: K'=96 (already even) -> 48 planar rows
    assert tree["c"]["w_in"]["packed_km"].shape == (48, 16)


# ---------------------------------------------------------- checkpoints ----
def test_quantized_ckpt_roundtrip_bit_exact(tmp_path):
    params = _params()
    plan = get_plan(MIXED)
    # a stale partial save must be garbage-collected, not break anything
    os.makedirs(tmp_path / "step_00000000.tmp_dead")
    save_quantized(str(tmp_path), 0, params, CFG, plan=plan)
    assert latest_step(str(tmp_path)) == 0
    assert not any(".tmp_" in n for n in os.listdir(tmp_path))

    restored, manifest = restore_quantized(str(tmp_path))
    assert manifest["format"] == "quantized-v1"
    assert qp.plan_from_dict(manifest["plan"]) == plan

    # the optional plan guard: a Runtime whose active plan differs from the
    # stored one must be rejected (mismatched backends would serve wrong
    # math silently), the matching one accepted
    restore_quantized(str(tmp_path), cfg=CFG,
                      rt=Runtime(quant_plan=MIXED, **RT_KW))
    with pytest.raises(AssertionError, match="does not match"):
        restore_quantized(str(tmp_path), cfg=CFG,
                          rt=Runtime(quant_backend="w4a4_packed", **RT_KW))

    ref = plan_pack_tree(params, CFG, plan, backends=CKPT_PACKED,
                         scale_dtype=jnp.bfloat16)
    assert_trees_bit_equal(restored, ref)
    # the format actually is packed: uint8 nibbles + bf16 scales present
    dtypes = {leaf.dtype.name for leaf in jax.tree_util.tree_leaves(restored)}
    assert "uint8" in dtypes and "bfloat16" in dtypes
    # non-uniform plan => per-repeat weight trees (block 0 float attention)
    assert set(restored["layers"]) == {"r0", "r1"}
    assert restored["layers"]["r0"]["u0"]["attn"]["wq"].dtype == jnp.float32
    assert restored["layers"]["r1"]["u0"]["attn"]["wq"]["packed"].dtype \
        == jnp.uint8


def test_quantized_ckpt_serves_bit_identical(tmp_path):
    """Acceptance: a non-uniform plan serves from a quantized checkpoint
    with bit-identical logits and generated tokens vs the same plan applied
    to float masters."""
    params = _params()
    rt = Runtime(quant_plan=MIXED, **RT_KW)
    save_quantized(str(tmp_path), 0, params, CFG, rt=rt)
    restored, _ = restore_quantized(str(tmp_path))
    ref = plan_pack_tree(params, CFG, get_plan(MIXED), backends=CKPT_PACKED,
                         scale_dtype=jnp.bfloat16)

    toks = _tokens(1, 8)
    la = np.asarray(forward(restored, toks, CFG, rt)[0], np.float32)
    lb = np.asarray(forward(ref, toks, CFG, rt)[0], np.float32)
    assert np.array_equal(la, lb)

    # end-to-end through the continuous-batching engine
    from repro.serving.engine import InferenceEngine

    sv = ServingConfig(layout="paged", max_batch=2, page_size=8,
                       num_pages=16, max_ctx=32)
    outs = []
    for p in (restored, ref):
        eng = InferenceEngine(CFG, rt, sv, params=p)
        for prompt in ([3, 1, 4, 1, 5], [9, 2, 6]):
            eng.submit(prompt, max_new=4)
        eng.run_until_idle()
        outs.append([r.tokens for r in sorted(eng.collect(),
                                              key=lambda r: r.rid)])
    assert outs[0] == outs[1]
