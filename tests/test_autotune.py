"""Autotuner: block-constraint invariants + on-disk cache round-trips."""

import json

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import autotune, ops
from repro.core.quant import pack_int4


@pytest.fixture(autouse=True)
def isolated_cache(tmp_path, monkeypatch):
    """Point the cache at a per-test file and reset in-memory state."""
    path = tmp_path / "autotune.json"
    monkeypatch.setenv(autotune.ENV_CACHE_PATH, str(path))
    autotune.reset()
    yield path
    autotune.reset()


# ------------------------------------------------------------- heuristics --
@pytest.mark.parametrize("M,K,N,G", [(1, 512, 512, 0), (256, 384, 128, 64),
                                     (7, 9, 24, 0), (128, 1024, 4096, 128)])
def test_default_blocks_respect_kernel_constraints(M, K, N, G):
    b = autotune.default_blocks(M, K, N, group_size=G)
    assert b["bk"] % 2 == 0                       # planar halves
    if G:
        assert b["bk"] % (2 * G) == 0             # whole groups per half
    assert b["bm"] >= 8 and b["bn"] >= 128


def test_candidate_blocks_include_default_and_are_unique():
    M, K, N, G = 64, 512, 256, 128
    cands = autotune.candidate_blocks(M, K, N, group_size=G)
    assert autotune.default_blocks(M, K, N, G) in cands
    assert len({tuple(sorted(c.items())) for c in cands}) == len(cands)
    for c in cands:
        assert c["bk"] % (2 * G) == 0


def test_get_blocks_without_cache_returns_defaults():
    assert autotune.get_blocks("int4_matmul", 32, 256, 128, "int8") \
        == autotune.default_blocks(32, 256, 128)


# ------------------------------------------------------------ cache round --
def test_tune_persists_and_get_blocks_round_trips(isolated_cache):
    """tune() -> JSON on disk -> fresh in-memory state reads it back."""
    target = {"bm": 64, "bn": 128, "bk": 256}

    def fake_timer(fn):
        blocks = fn()                             # make_call returns blocks
        return 1.0 if blocks == target else 100.0

    best, us = autotune.tune(
        "int4_matmul", lambda blocks: (lambda b=blocks: b),
        64, 512, 256, "int8", timer=fake_timer)
    assert best == target and us == 1.0
    assert isolated_cache.exists()

    autotune.reset()                              # force a re-read from disk
    got = autotune.get_blocks("int4_matmul", 64, 512, 256, "int8")
    assert got == target


def test_tagged_entry_wins_over_untagged(isolated_cache):
    key_args = ("w4a16_matmul", 8, 256, 512, "bfloat16")
    autotune._CACHE[autotune.cache_key(*key_args, group_size=0)] = \
        {"bm": 128, "bn": 128, "bk": 512, "us": 5.0}
    autotune._CACHE[autotune.cache_key(*key_args, group_size=0,
                                       tag="ffn.w_in")] = \
        {"bm": 32, "bn": 128, "bk": 256, "us": 2.0}
    autotune.save_cache()
    autotune.reset()
    tagged = autotune.get_blocks(*key_args, tag="ffn.w_in")
    untagged = autotune.get_blocks(*key_args)
    assert tagged == {"bm": 32, "bn": 128, "bk": 256}
    assert untagged == {"bm": 128, "bn": 128, "bk": 512}


def test_cache_key_distinguishes_dtype_shape_backend():
    keys = {
        autotune.cache_key("int4_matmul", 8, 256, 512, "int8"),
        autotune.cache_key("int4_matmul", 8, 256, 512, "bfloat16"),
        autotune.cache_key("int4_matmul", 16, 256, 512, "int8"),
        autotune.cache_key("int4_matmul", 8, 256, 512, "int8", backend="tpu"),
        autotune.cache_key("w4a16_matmul", 8, 256, 512, "int8"),
        autotune.cache_key("int4_matmul", 8, 256, 512, "int8", group_size=64),
    }
    assert len(keys) == 6


def test_corrupt_cache_file_is_ignored(isolated_cache):
    isolated_cache.write_text("{not json")
    assert autotune.load_cache() == 0
    assert autotune.get_blocks("int4_matmul", 8, 64, 64, "int8") \
        == autotune.default_blocks(8, 64, 64)


def test_load_skips_malformed_entries(isolated_cache):
    isolated_cache.write_text(json.dumps({
        "good|key": {"bm": 8, "bn": 128, "bk": 64, "us": 1.0},
        "bad|key": {"bm": 8},
        "worse|key": 17,
    }))
    assert autotune.load_cache() == 1


def test_tune_skips_failing_candidates(isolated_cache):
    # a rejected tile raises one of the lowering/compile classes the tuner
    # catches (here: no Mosaic lowering); each skip bumps the rejection
    # counter
    boom = {"bm": 32, "bn": 128, "bk": 128}

    def make_call(blocks):
        def run():
            if blocks == boom:
                raise NotImplementedError("unsupported tile")
            return blocks
        return run

    def fake_timer(fn):
        fn()
        return 10.0

    from repro.observability.metrics import global_registry
    rejected = global_registry().counter(
        "autotune_tiles_rejected_total",
        "autotune candidates skipped on lowering/compile failure",
        op="int4_matmul")
    before = rejected.value
    best, _ = autotune.tune("int4_matmul", make_call, 64, 512, 256, "int8",
                            candidates=[boom, {"bm": 64, "bn": 128, "bk": 256}],
                            timer=fake_timer)
    assert best == {"bm": 64, "bn": 128, "bk": 256}
    assert rejected.value == before + 1


def test_tune_propagates_programming_errors(isolated_cache):
    # a TypeError is a bug in make_call, not a rejected tile: the narrowed
    # except must let it escape instead of silently discarding the
    # candidate
    def make_call(blocks):
        def run():
            raise TypeError("bug, not a bad tile")
        return run

    with pytest.raises(TypeError):
        autotune.tune("int4_matmul", make_call, 64, 512, 256, "int8",
                      candidates=[{"bm": 64, "bn": 128, "bk": 256}],
                      timer=lambda fn: (fn(), 10.0)[1])


def test_tune_key_matches_ops_lookup_key(isolated_cache):
    """The benchmark tunes under the key the ops wrapper reads at serving
    time (op, shape, *activation* dtype, group size).  A drift here makes
    every tuned entry dead weight, so pin the agreement."""
    from repro.kernels.ops import _blocks

    target = {"bm": 8, "bn": 32, "bk": 64}
    autotune.tune("int4_matmul", lambda b: (lambda: b), 8, 64, 32, "int8",
                  timer=lambda fn: 1.0, candidates=[target])
    assert _blocks("int4_matmul", 8, 64, 32, jnp.int8, 0, "", {}) == target
    # a site-tagged lookup falls back to the untagged tuned entry
    assert _blocks("int4_matmul", 8, 64, 32, jnp.int8, 0, "ffn.w_in", {}) \
        == target


# ----------------------------------------------------------- integration ---
def test_tuned_blocks_flow_into_kernel_call(isolated_cache):
    """End-to-end: a cache entry changes the tiles the ops wrapper uses, and
    the result still matches the oracle."""
    rng = np.random.default_rng(5)
    M, K, N = 16, 128, 64
    aq = jnp.asarray(rng.integers(-8, 8, (M, K), np.int8))
    a_s = jnp.ones((M, 1), jnp.float32)
    wq = jnp.asarray(rng.integers(-8, 8, (K, N), np.int8))
    w_s = jnp.ones((1, N), jnp.float32)
    wp = pack_int4(wq, -1)

    autotune._CACHE[autotune.cache_key("int4_matmul", M, K, N, "int8")] = \
        {"bm": 8, "bn": 32, "bk": 64, "us": 1.0}
    got = ops.int4_matmul(aq, a_s, wp, w_s, interpret=True)
    exp = jnp.dot(aq.astype(jnp.int32), wq.astype(jnp.int32)).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), rtol=1e-6)
