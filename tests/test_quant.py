"""Quantization substrate: invariants (hypothesis property tests) + qdense
backend agreement, including the end-to-end netlist oracle."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.qlinear import QuantConfig, qdense
from repro.core.quant import (
    dequantize,
    fake_quant,
    group_quantize,
    pack_int4,
    quant_scale,
    quantize,
    unpack_int4,
)


# ------------------------------------------------------ property: quantize --
@given(
    st.lists(st.floats(-100, 100, allow_nan=False, width=32), min_size=4,
             max_size=64).map(np.asarray),
    st.sampled_from([4, 8]),
)
@settings(max_examples=50, deadline=None)
def test_quant_roundtrip_error_bounded(vals, bits):
    """|x - dq(q(x))| <= scale/2 for values inside the clip range."""
    x = jnp.asarray(vals, jnp.float32)
    scale = quant_scale(x, axis=None, bits=bits)
    q = quantize(x, scale, bits=bits)
    err = jnp.abs(dequantize(q, scale) - x)
    assert float(jnp.max(err)) <= float(scale) / 2 + 1e-6


@given(st.integers(-8, 7), st.integers(-8, 7))
@settings(max_examples=64, deadline=None)
def test_netlist_product_matches_int_mul(a, b):
    """Property: the paper's circuit multiplies any signed int4 pair exactly."""
    from repro.core import build_proposed_mult4
    from repro.core.quant import to_unsigned_mag

    nl = build_proposed_mult4()
    qa, qb = jnp.int8(a), jnp.int8(b)
    ma, sa = to_unsigned_mag(qa)
    mb, sb = to_unsigned_mag(qb)
    assert int(nl(ma, mb)) * int(sa) * int(sb) == a * b


@given(st.integers(1, 8).map(lambda n: 2 * n))
@settings(max_examples=10, deadline=None)
def test_pack_unpack_roundtrip_property(n):
    rng = np.random.default_rng(n)
    q = jnp.asarray(rng.integers(-8, 8, size=(n, n), dtype=np.int8))
    np.testing.assert_array_equal(np.asarray(unpack_int4(pack_int4(q))), np.asarray(q))


# ------------------------------------------------------------- fake quant --
def test_fake_quant_ste_gradient_is_identity_inside_range():
    x = jnp.linspace(-1.0, 1.0, 32)
    g = jax.grad(lambda v: jnp.sum(fake_quant(v, axis=None, bits=4)))(x)
    np.testing.assert_allclose(np.asarray(g), np.ones(32), rtol=1e-6)


def test_group_quantize_shapes():
    w = jnp.asarray(np.random.default_rng(0).standard_normal((256, 16), dtype=np.float32))
    q, s = group_quantize(w, 64)
    assert q.shape == (256, 16) and s.shape == (4, 1, 16)


# -------------------------------------------------------- qdense backends --
@pytest.mark.parametrize("backend", ["int_sim", "pallas_int4"])
def test_qdense_int_backends_agree(backend):
    rng = np.random.default_rng(7)
    w = jnp.asarray(rng.standard_normal((64, 48), dtype=np.float32)) * 0.1
    x = jnp.asarray(rng.standard_normal((5, 64), dtype=np.float32))
    y_sim = qdense(w, x, QuantConfig(backend="int_sim"))
    y = qdense(w, x, QuantConfig(backend=backend))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_sim), rtol=1e-5, atol=1e-5)


def test_qdense_netlist_oracle_matches_int_sim():
    """The full FPGA-circuit GEMM equals the int_sim GEMM bit-for-bit."""
    rng = np.random.default_rng(11)
    w = jnp.asarray(rng.standard_normal((16, 8), dtype=np.float32))
    x = jnp.asarray(rng.standard_normal((3, 16), dtype=np.float32))
    y_net = qdense(w, x, QuantConfig(backend="netlist"))
    y_sim = qdense(w, x, QuantConfig(backend="int_sim"))
    np.testing.assert_array_equal(np.asarray(y_net), np.asarray(y_sim))


def test_qdense_quant_error_small_vs_float():
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.standard_normal((128, 64), dtype=np.float32)) * 0.05
    x = jnp.asarray(rng.standard_normal((16, 128), dtype=np.float32))
    y_f = qdense(w, x, QuantConfig(backend="float"))
    for backend in ("fake_quant", "int_sim", "w4a16"):
        y_q = qdense(w, x, QuantConfig(backend=backend))
        rel = float(jnp.linalg.norm(y_q - y_f) / jnp.linalg.norm(y_f))
        assert rel < 0.25, (backend, rel)   # int4 error band


def test_qdense_bias_and_dtype():
    w = jnp.ones((8, 4), jnp.float32)
    x = jnp.ones((2, 8), jnp.bfloat16)
    b = jnp.arange(4, dtype=jnp.float32)
    y = qdense(w, x, QuantConfig(backend="float"), bias=b)
    assert y.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(y, np.float32)[0], 8.0 + np.arange(4))
