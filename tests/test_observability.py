"""Serving telemetry: metrics registry semantics (percentile edges, text
exposition), trace ring overflow + Perfetto JSON round-trip, the recompile
sentinel (once per new bucket shape, loud on steady-state), and end-to-end
neutrality — telemetry on vs off generates identical tokens."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import Runtime, ServingConfig, get_config
from repro.observability import (
    NULL_REGISTRY,
    NULL_TRACE,
    JitWatch,
    MetricsRegistry,
    RecompileError,
    Telemetry,
    TraceRecorder,
)
from repro.serving.api import poisson_trace, run_trace
from repro.serving.engine import InferenceEngine


# ----------------------------------------------------------------- metrics --
def test_counter_gauge_identity_and_labels():
    reg = MetricsRegistry()
    c = reg.counter("reqs_total", "help text")
    c.inc()
    c.inc(3)
    assert reg.counter("reqs_total").value == 4          # same cell
    # labelled metrics are distinct cells per label set
    reg.counter("ops_total", op="a").inc(2)
    reg.counter("ops_total", op="b").inc(5)
    snap = reg.snapshot()["counters"]
    assert snap["reqs_total"] == 4
    assert snap['ops_total{op="a"}'] == 2
    assert snap['ops_total{op="b"}'] == 5
    reg.gauge("depth").set(7)
    assert reg.snapshot()["gauges"]["depth"] == 7.0


def test_histogram_single_observation_is_exact():
    reg = MetricsRegistry()
    h = reg.histogram("lat_us")
    h.observe(123.4)
    s = h.summary()
    assert s["count"] == 1 and s["sum"] == pytest.approx(123.4)
    # clamping to the observed [min, max] makes one-value histograms exact
    assert s["p50"] == s["p95"] == s["p99"] == pytest.approx(123.4)
    assert s["min"] == s["max"] == pytest.approx(123.4)


def test_histogram_percentiles_bounded_and_monotonic():
    h = MetricsRegistry().histogram("lat_us")
    rng = np.random.default_rng(0)
    vals = rng.uniform(10.0, 50_000.0, size=500)
    for v in vals:
        h.observe(v)
    s = h.summary()
    assert s["count"] == 500
    assert s["min"] == pytest.approx(vals.min())
    assert s["max"] == pytest.approx(vals.max())
    assert s["min"] <= s["p50"] <= s["p95"] <= s["p99"] <= s["max"]
    # bucketed estimate stays in the ballpark of the exact percentile
    assert s["p50"] == pytest.approx(np.percentile(vals, 50), rel=1.0)


def test_histogram_all_equal_and_empty():
    h = MetricsRegistry().histogram("lat_us")
    assert h.percentile(50) is None
    assert h.summary()["p99"] is None
    for _ in range(10):
        h.observe(400.0)
    assert h.percentile(50) == pytest.approx(400.0)
    assert h.percentile(99) == pytest.approx(400.0)


def test_render_text_prometheus_shape():
    reg = MetricsRegistry()
    reg.counter("reqs_total", "finished requests").inc(2)
    reg.gauge("depth", "queue depth").set(3)
    reg.histogram("lat_us", "latency", buckets=(10.0, 100.0)).observe(50.0)
    text = reg.render_text()
    assert "# HELP reqs_total finished requests" in text
    assert "# TYPE reqs_total counter" in text
    assert "reqs_total 2" in text
    assert "depth 3.0" in text
    # cumulative buckets + the open-ended +Inf bucket + _sum/_count
    assert 'lat_us_bucket{le="10"} 0' in text
    assert 'lat_us_bucket{le="100"} 1' in text
    assert 'lat_us_bucket{le="+Inf"} 1' in text
    assert "lat_us_sum 50.0" in text
    assert "lat_us_count 1" in text


def test_null_registry_is_inert():
    m = NULL_REGISTRY.counter("x")
    m.inc()
    NULL_REGISTRY.gauge("y").set(1)
    NULL_REGISTRY.histogram("z").observe(2.0)
    assert NULL_REGISTRY.snapshot() == {
        "counters": {}, "gauges": {}, "histograms": {}}
    assert NULL_REGISTRY.render_text() == ""


# ------------------------------------------------------------------- trace --
def test_trace_ring_overflow_keeps_newest():
    tr = TraceRecorder(capacity=4)
    for i in range(10):
        tr.instant(f"i{i}", tid=0)
    assert tr.dropped == 6
    names = [ev["name"] for ev in tr.events()]
    assert names == ["i6", "i7", "i8", "i9"]          # oldest-first unroll
    assert tr.to_chrome()["otherData"]["dropped_events"] == 6


def test_trace_span_and_complete():
    tr = TraceRecorder()
    t0 = tr.now()
    with tr.span("work", tid=1, rid=7):
        pass
    tr.complete("manual", tid=2, t0=t0, t1=t0 + 100.0)
    evs = tr.events()
    assert [e["ph"] for e in evs] == ["X", "X"]
    assert evs[0]["args"] == {"rid": 7}
    assert evs[1]["dur"] == pytest.approx(100.0)
    assert all(e["ts"] >= 0 and e["dur"] >= 0 for e in evs)


def test_trace_perfetto_json_round_trip(tmp_path):
    tr = TraceRecorder()
    tr.lane(0, "engine")
    tr.lane(1, "slot0")
    with tr.span("step", tid=0, decode_rows=2):
        pass
    path = str(tmp_path / "trace.json")
    tr.save(path)
    with open(path) as f:
        doc = json.load(f)
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    lanes = {e["tid"]: e["args"]["name"] for e in meta
             if e["name"] == "thread_name"}
    assert lanes == {0: "engine", 1: "slot0"}
    spans = [e for e in evs if e["ph"] == "X"]
    assert len(spans) == 1 and spans[0]["name"] == "step"
    assert set(spans[0]) >= {"ts", "dur", "pid", "tid"}
    assert doc["displayTimeUnit"] == "ms"


def test_null_trace_is_inert(tmp_path):
    with NULL_TRACE.span("x", tid=0):
        pass
    assert NULL_TRACE.now() == 0.0
    path = str(tmp_path / "empty.json")
    NULL_TRACE.save(path)
    with open(path) as f:
        assert json.load(f)["traceEvents"] == []


# --------------------------------------------------------------- jit watch --
class _FakeJit:
    """Stub with a controllable cache: set .size to simulate compiles."""

    def __init__(self):
        self.size = 0

    def _cache_size(self):
        return self.size


def test_jit_watch_counts_once_per_new_shape():
    reg = MetricsRegistry()
    w = JitWatch(reg)
    f = _FakeJit()
    w.register("decode", f)
    f.size = 1                                   # first bucket compiles
    assert w.after_call("decode", (2, 1), step=0) == 1
    assert w.after_call("decode", (2, 1), step=1) == 0   # cached replay
    f.size = 2                                   # second bucket compiles
    assert w.after_call("decode", (4, 1), step=2) == 1
    assert w.total == 2 and w.steady_state == 0
    assert reg.snapshot()["counters"]['jit_compiles_total{fn="decode"}'] == 2


def test_jit_watch_flags_steady_state_and_strict_raises():
    w = JitWatch(MetricsRegistry())
    f = _FakeJit()
    w.register("decode", f)
    f.size = 1
    w.after_call("decode", (2, 1), step=0)
    f.size = 2                                   # recompile, same shape
    assert w.after_call("decode", (2, 1), step=5) == 1
    assert w.steady_state == 1
    assert w.snapshot()["events"][-1]["steady_state"] is True

    strict = JitWatch(MetricsRegistry(), strict=True)
    g = _FakeJit()
    strict.register("decode", g)
    g.size = 1
    strict.after_call("decode", (2, 1))
    g.size = 2
    with pytest.raises(RecompileError, match="decode"):
        strict.after_call("decode", (2, 1))


def test_jit_watch_absorb_rebaselines():
    w = JitWatch(strict=True)
    f = _FakeJit()
    w.register("decode", f)
    f.size = 1
    w.after_call("decode", (2, 1))
    f.size = 3                      # out-of-loop probe calls (profile())
    w.absorb()
    assert w.after_call("decode", (2, 1)) == 0   # not a steady-state hit


def test_jit_watch_novelty_fallback_without_cache_api():
    w = JitWatch(strict=True)
    w.register("decode", lambda x: x)            # no _cache_size
    assert w.after_call("decode", (2, 1)) == 1   # new shape ~ compile
    assert w.after_call("decode", (2, 1)) == 0   # degrades to never-fires
    assert w.steady_state == 0


def test_jit_watch_on_real_jit():
    w = JitWatch()
    f = jax.jit(lambda x: x + 1)
    w.register("f", f)
    f(jnp.zeros((2,), jnp.float32))
    assert w.after_call("f", (2,)) == 1
    f(jnp.zeros((2,), jnp.float32))
    assert w.after_call("f", (2,)) == 0          # cache hit
    f(jnp.zeros((3,), jnp.float32))
    assert w.after_call("f", (3,)) == 1
    assert w.total == 2 and w.steady_state == 0


# -------------------------------------------------------------- engine e2e --
@pytest.fixture(scope="module")
def reduced_cfg():
    return get_config("qwen2-0.5b").reduced()


def _engine(cfg, telemetry=None, clock=None):
    rt = Runtime(quant_backend="float", cache_dtype="bfloat16", remat="none",
                 loss_chunk=0)
    sv = ServingConfig(layout="paged", max_batch=2, page_size=8,
                       num_pages=32, max_ctx=32)
    kw = {"clock": clock} if clock is not None else {}
    return InferenceEngine(cfg, rt, sv, seed=0, telemetry=telemetry, **kw)


def test_engine_telemetry_is_token_identity_neutral(reduced_cfg):
    trace = poisson_trace(4, 1.0, [8], [4], reduced_cfg.vocab, seed=5)
    # full telemetry, strict sentinel: a steady-state recompile would raise
    tm = Telemetry(metrics=True, trace=True, strict_recompiles=True)
    eng = _engine(reduced_cfg, telemetry=tm)
    eng.warmup([8])
    stats, fin = run_trace(eng, trace)
    _, fin_off = run_trace(_engine(reduced_cfg, Telemetry.disabled()), trace)
    assert [r.tokens for r in fin] == [r.tokens for r in fin_off]

    # the trace covers every engine step, plus a residency span per request
    names = [e["name"] for e in tm.trace.events()]
    assert names.count("step") == stats["steps"]
    for r in fin:
        assert f"r{r.rid}" in names
    # registry agrees with the engine's own counts; latency histograms
    # carry the typed outcome label (all four requests finished cleanly)
    hists = stats["metrics"]["histograms"]
    assert hists['ttft_us{outcome="ok"}']["count"] \
        == stats["requests_finished"] == 4
    assert hists["step_wall_us"]["count"] == stats["steps"]
    counters = stats["metrics"]["counters"]
    assert counters["decode_tokens_total"] == stats["decode_tokens"]
    assert counters["requests_finished_total"] == 4
    assert counters['requests_retired_total{outcome="ok"}'] == 4
    assert stats["outcomes"] == {"ok": 4}
    # warmup compiled every bucket: zero steady-state recompiles (strict
    # mode would have raised) and a non-empty compile ledger
    assert stats["recompiles"]["steady_state"] == 0
    assert stats["recompiles"]["total"] > 0
    # Prometheus exposition renders the same registry
    assert 'ttft_us_count{outcome="ok"} 4' in eng.metrics.render_text()


def test_engine_stats_with_zero_finished_requests(reduced_cfg):
    eng = _engine(reduced_cfg, Telemetry.disabled())
    stats = eng.stats()
    assert stats["requests_finished"] == 0
    # no fake numbers: every derived latency degrades to None
    for key in ("latency_p50_s", "latency_mean_s", "ttft_p50_s",
                "ttft_mean_s", "decode_tok_per_s"):
        assert stats[key] is None
    assert stats["metrics"] == {"counters": {}, "gauges": {},
                                "histograms": {}}


def test_engine_ttft_survives_zero_clock(reduced_cfg):
    # a fake clock pinned at 0.0 makes t_first == 0.0 exactly; the stats
    # must treat that as a real first-token time, not a missing one
    trace = poisson_trace(2, 1.0, [8], [2], reduced_cfg.vocab, seed=5)
    eng = _engine(reduced_cfg, clock=lambda: 0.0)
    stats, fin = run_trace(eng, trace)
    assert len(fin) == 2
    assert stats["ttft_p50_s"] == 0.0            # present, not None
    assert stats["latency_p50_s"] == 0.0


def test_engine_profile_stamped_with_step(reduced_cfg):
    trace = poisson_trace(2, 1.0, [8], [2], reduced_cfg.vocab, seed=5)
    tm = Telemetry(metrics=True, strict_recompiles=True)
    eng = _engine(reduced_cfg, telemetry=tm)
    eng.warmup([8])
    run_trace(eng, trace)
    prof = eng.profile()
    stats = eng.stats()
    assert prof["at_step"] == stats["steps"]
    assert stats["profile_at_step"] == stats["steps"]
    # profile()'s probe compiles were absorbed: decoding again under the
    # strict sentinel must not flag them as steady-state recompiles
    eng.submit(np.arange(8, dtype=np.int32), 2)
    eng.run_until_idle()


def test_telemetry_bundle_modes():
    tm = Telemetry()
    assert tm.registry.enabled and not tm.trace.enabled
    assert tm.enabled
    off = Telemetry.disabled()
    assert not off.enabled
    assert off.jit_watch.after_call("decode", (1, 1)) == 0
