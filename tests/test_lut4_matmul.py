"""Table-lookup W4A4 GEMM (`kernels/lut4_matmul.py`) and the `lut4` backend:
kernel vs XLA-twin bitwise parity, plan/serving token identity vs the int4
backend, quantized-checkpoint round-trip with lut4 sites, autotune tags."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import restore_quantized, save_quantized
from repro.configs import Runtime, ServingConfig, get_config
from repro.core.qlinear import QuantConfig, qdense
from repro.core.quant import pack_int4
from repro.core.quant_plan import CKPT_PACKED, get_plan, plan_pack_tree
from repro.kernels import autotune, ops, ref
from repro.kernels.lut4_matmul import lut4_matmul
from repro.kernels.packing import (
    nibble_product_tables,
    nmajor_to_kmajor,
    table_take,
)
from repro.models import forward, init_model

CFG = get_config("qwen2-0.5b").reduced(n_layers=2)
RT_KW = dict(scan_layers=True, attn_impl="chunked", attn_chunk_q=8,
             loss_chunk=0, remat="none")


def _rand_case(M, K, N, seed=0):
    rng = np.random.default_rng(seed)
    a_q = jnp.asarray(rng.integers(-8, 8, (M, K)), jnp.int8)
    w_q = jnp.asarray(rng.integers(-8, 8, (K, N)), jnp.int8)
    a_s = jnp.asarray(rng.uniform(0.01, 1.0, (M, 1)), jnp.float32)
    w_s = jnp.asarray(rng.uniform(0.01, 1.0, (1, N)), jnp.float32)
    return a_q, a_s, pack_int4(w_q, axis=-1), w_s


# --------------------------------------------------------------- tables ----
def test_nibble_product_tables_exact():
    t_lo, t_hi = nibble_product_tables()
    assert t_lo.shape == t_hi.shape == (16, 256)
    assert t_lo.dtype == t_hi.dtype == np.int8
    sext = lambda v: (v ^ 8) - 8
    for a in range(16):
        for b in range(0, 256, 7):          # stride keeps the loop cheap
            assert t_lo[a, b] == sext(a) * sext(b & 0xF)
            assert t_hi[a, b] == sext(a) * sext(b >> 4)


def test_make_product_lut_is_view_of_gemm_tables():
    """ref.make_product_lut deduped into the table builder: same 256
    entries the elementwise kernels always used."""
    lut = ref.make_product_lut()
    sext = lambda v: (v ^ 8) - 8
    for a in range(16):
        for b in range(16):
            assert lut[(a << 4) | b] == sext(a) * sext(b)


def test_table_take_semantics():
    table = jnp.asarray(np.arange(32, dtype=np.int32).reshape(4, 8))
    rows = jnp.asarray([2, 0])
    lanes = jnp.asarray([[1, 7], [0, 3]])
    got = np.asarray(table_take(table, rows, lanes))
    assert got.tolist() == [[17, 23], [0, 3]]


# --------------------------------------------------------------- parity ----
ODD_SHAPES = [(1, 2, 2), (3, 5, 2), (7, 13, 10), (33, 57, 34),
              (8, 512, 512), (129, 511, 130)]


@pytest.mark.parametrize("M,K,N", ODD_SHAPES)
def test_table_oracle_bitwise_equals_int_dot(M, K, N):
    """The rank-1 identity that makes the XLA twin legitimate: every
    partial product read from the tables == the int8 dot, bit for bit."""
    a_q, a_s, wp, w_s = _rand_case(M, K, N, seed=M * 1000 + N)
    want = np.asarray(ref.int4_matmul_ref(a_q, a_s, wp, w_s))
    got = np.asarray(ref.lut4_matmul_ref(a_q, a_s, wp, w_s))
    assert np.array_equal(want, got)


@pytest.mark.parametrize("M,K,N", ODD_SHAPES)
def test_kernel_bitwise_parity_odd_shapes(M, K, N):
    a_q, a_s, wp, w_s = _rand_case(M, K, N, seed=M + K + N)
    want = np.asarray(ref.int4_matmul_ref(a_q, a_s, wp, w_s))
    got = np.asarray(lut4_matmul(a_q, a_s, nmajor_to_kmajor(wp), w_s,
                                 bm=32, bn=32, bk=16, interpret=True))
    assert np.array_equal(want, got)


@pytest.mark.parametrize("blocks", [dict(bm=8, bn=128, bk=2),
                                    dict(bm=32, bn=256, bk=64),
                                    dict(bm=128, bn=128, bk=256)])
def test_kernel_parity_across_block_shapes(blocks):
    a_q, a_s, wp, w_s = _rand_case(48, 96, 160, seed=9)
    want = np.asarray(ref.int4_matmul_ref(a_q, a_s, wp, w_s))
    got = np.asarray(lut4_matmul(a_q, a_s, nmajor_to_kmajor(wp), w_s,
                                 interpret=True, **blocks))
    assert np.array_equal(want, got)


def test_ops_dispatch_modes(monkeypatch):
    """interpret / XLA-twin dispatch agree bitwise, for both the serialized
    and the kmajor entry points, including the env-var override."""
    a_q, a_s, wp, w_s = _rand_case(17, 33, 26, seed=3)
    wk = nmajor_to_kmajor(wp)
    want = np.asarray(ref.int4_matmul_ref(a_q, a_s, wp, w_s))
    for call in (lambda **kw: ops.lut4_matmul(a_q, a_s, wp, w_s, **kw),
                 lambda **kw: ops.lut4_matmul_kmajor(a_q, a_s, wk, w_s, **kw)):
        assert np.array_equal(want, np.asarray(call(interpret=True)))
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
        assert np.array_equal(want, np.asarray(call()))
        monkeypatch.delenv("REPRO_PALLAS_INTERPRET")
        assert np.array_equal(want, np.asarray(call()))   # XLA twin on CPU


# ------------------------------------------------------- plan / serving ----
def test_lut4_backend_matches_int_sim_bitwise():
    rng = np.random.default_rng(4)
    w = jnp.asarray(rng.standard_normal((96, 64)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 5, 96)), jnp.float32)
    ya = qdense(w, x, QuantConfig(backend="int_sim"))
    yb = qdense(w, x, QuantConfig(backend="lut4"))
    assert np.array_equal(np.asarray(ya), np.asarray(yb))


@pytest.mark.parametrize("g", [0, 32])
def test_lut4_group_sizes_coerce_per_channel(g):
    """`pat=lut4/gN` parses, and packing coerces to per-channel scales (the
    int32 accumulation runs over full K, like the other W4A4 backends)."""
    plan = get_plan(f"ffn.*=lut4/g{g};*=int_sim" if g else
                    "ffn.*=lut4;*=int_sim")
    qc = plan.resolve("block[0].ffn.w_in")
    assert qc.backend == "lut4" and qc.group_size == g
    params = init_model(jax.random.PRNGKey(0), CFG)
    packed = plan_pack_tree(params, CFG, plan, backends=CKPT_PACKED)
    layers = packed["layers"]           # repeat-uniform plans keep "u0"
    ff = (layers["u0"] if "u0" in layers else layers["r0"]["u0"]
          )["ffn"]["w_in"]
    assert ff["packed"].dtype == jnp.uint8
    # per-channel scales: no group axis ([..., 1, N], same rank as packed)
    assert ff["scale"].ndim == ff["packed"].ndim
    assert ff["scale"].shape[-2] == 1
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, CFG.vocab,
                              dtype=jnp.int32)
    rt = Runtime(quant_plan=f"ffn.*=lut4/g{g};*=int_sim" if g else
                 "ffn.*=lut4;*=int_sim", **RT_KW)
    out = forward(packed, toks, CFG, rt)[0]
    assert np.all(np.isfinite(np.asarray(out, np.float32)))


def test_lut4_uniform_plan_token_identity_vs_int4():
    """Engine acceptance: a uniform lut4 plan generates the exact token
    stream of the existing int4 backend (identical integer math)."""
    from repro.serving.engine import InferenceEngine

    params = init_model(jax.random.PRNGKey(0), CFG)
    sv = ServingConfig(layout="paged", max_batch=2, page_size=8,
                       num_pages=16, max_ctx=32)
    outs = []
    for spec in ("*=lut4;lm_head=float", "*=int_sim;lm_head=float"):
        eng = InferenceEngine(CFG, Runtime(quant_plan=spec, **RT_KW), sv,
                              params=params)
        for prompt in ([3, 1, 4, 1, 5], [9, 2, 6]):
            eng.submit(prompt, max_new=4)
        eng.run_until_idle()
        outs.append([r.tokens for r in sorted(eng.collect(),
                                              key=lambda r: r.rid)])
    assert outs[0] == outs[1]


# ---------------------------------------------------------- checkpoints ----
MIXED_LUT4 = "ffn.*=lut4;attn.*=int_sim;lm_head=float;*=w4a16"


def test_quantized_ckpt_roundtrip_mixed_lut4(tmp_path):
    params = init_model(jax.random.PRNGKey(0), CFG)
    rt = Runtime(quant_plan=MIXED_LUT4, **RT_KW)
    save_quantized(str(tmp_path), 0, params, CFG, rt=rt)
    restored, manifest = restore_quantized(str(tmp_path), cfg=CFG, rt=rt)

    # the manifest records which backend each packed site was laid out for
    sb = manifest["site_backends"]
    assert sb.get("block[0].ffn.w_in") == "lut4"
    assert sb.get("block[0].attn.qkv") == "int_sim"
    assert "lm_head" not in sb                    # float site stays a master

    ref_tree = plan_pack_tree(params, CFG, get_plan(MIXED_LUT4),
                              backends=CKPT_PACKED,
                              scale_dtype=jnp.bfloat16)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, CFG.vocab,
                              dtype=jnp.int32)
    la = np.asarray(forward(restored, toks, CFG, rt)[0], np.float32)
    lb = np.asarray(forward(ref_tree, toks, CFG, rt)[0], np.float32)
    assert np.array_equal(la, lb)

    # restoring under a plan that resolves a lut4 site to another backend
    # must fail per-site, not silently serve nibble-unpack w4a4
    with pytest.raises(AssertionError, match="lut4"):
        restore_quantized(
            str(tmp_path), cfg=CFG,
            rt=Runtime(quant_plan="ffn.*=int_sim;attn.*=int_sim;"
                       "lm_head=float;*=w4a16", **RT_KW))


def test_packed_weight_unknown_backend_is_loud():
    """A packed dict reaching a backend with no packed path raises instead
    of silently dropping into the w4a16 dequant branch (wrong math)."""
    from repro.core.qlinear import pack_weight_nd

    rng = np.random.default_rng(5)
    w = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 64)), jnp.float32)
    pw = pack_weight_nd(w, QuantConfig(backend="lut4", group_size=0))
    with pytest.raises(ValueError, match="no packed-weight path"):
        qdense(pw, x, QuantConfig(backend="netlist"))


# -------------------------------------------------------------- autotune ----
@pytest.fixture
def isolated_cache(tmp_path, monkeypatch):
    path = tmp_path / "autotune.json"
    monkeypatch.setenv(autotune.ENV_CACHE_PATH, str(path))
    autotune.reset()
    yield path
    autotune.reset()


def test_lut4_blocks_constraints_and_candidates():
    for (M, K, N) in [(1, 512, 512), (8, 512, 512), (256, 511, 130)]:
        b = autotune.lut4_default_blocks(M, K, N)
        assert b["bk"] % 2 == 0 and b["bm"] >= 8 and b["bn"] >= 128
        cands = autotune.lut4_candidate_blocks(M, K, N)
        assert b in cands
        assert len({tuple(sorted(c.items())) for c in cands}) == len(cands)
        for c in cands:
            assert c["bk"] % 2 == 0
    assert autotune.get_blocks("gemm.lut4", 8, 512, 512, "int8") \
        == autotune.lut4_default_blocks(8, 512, 512)


def test_lut4_autotune_tag_roundtrip(isolated_cache):
    """tune() under op gemm.lut4 with a site tag persists, and the exact
    key ops.lut4_matmul_kmajor looks up wins over the untagged default."""
    cands = autotune.lut4_candidate_blocks(8, 512, 512)
    target = cands[-1]

    def fake_timer(fn):
        return 1.0 if fn() == target else 100.0

    tag = "block[0].ffn.w_in"
    best, us = autotune.tune("gemm.lut4", lambda b: (lambda b=b: b),
                             8, 512, 512, "int8", tag=tag, timer=fake_timer)
    assert best == target and us == 1.0
    assert autotune.get_blocks("gemm.lut4", 8, 512, 512, "int8", tag=tag) \
        == target
    # untagged lookup seeded from the tagged search (setdefault)
    assert autotune.get_blocks("gemm.lut4", 8, 512, 512, "int8") == target
    # fresh process state reads the persisted entry back
    autotune.reset()
    assert autotune.get_blocks("gemm.lut4", 8, 512, 512, "int8", tag=tag) \
        == target
