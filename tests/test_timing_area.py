"""Paper Tables II & III: resource counts and CPD orderings from our models."""

import pytest

from repro.core import (
    PUBLISHED_ROWS,
    analyze,
    build_acc_mult4,
    build_lm_mult4,
    build_proposed_mult4,
    resources,
)
from repro.core.pipeline_mult import pipelined_report


def test_table2_proposed_resources():
    r = resources(build_proposed_mult4())
    assert r["luts"] == 11 and r["carry4"] == 2          # paper Table II row 1


def test_table2_lm_resources():
    r = resources(build_lm_mult4())
    assert r["luts"] == 12 and r["carry4"] == 1          # paper Table II row 2


def test_table2_proposed_is_minimum():
    ours = resources(build_proposed_mult4())["luts"]
    for name, row in PUBLISHED_ROWS.items():
        if name != "proposed":
            assert ours < row["luts"], name


def test_table3_proposed_cpd_matches_paper():
    t = analyze(build_proposed_mult4())
    assert abs(t["cpd"] - 2.750) < 1e-6                   # calibrated
    assert abs(t["logic"] - 1.302) < 1e-6
    assert abs(t["net"] - 1.448) < 1e-6


def test_table3_orderings_emerge_from_model():
    cpd = {
        "proposed": analyze(build_proposed_mult4())["cpd"],
        "lm": analyze(build_lm_mult4())["cpd"],
        "acc": analyze(build_acc_mult4())["cpd"],
    }
    assert cpd["proposed"] < cpd["lm"] < cpd["acc"]       # paper Table III order
    # LM's penalty is routing CO3 through the fabric: net-dominated.
    lm = analyze(build_lm_mult4())
    assert lm["net"] > analyze(build_proposed_mult4())["net"]


def test_lm_within_10pct_of_published():
    assert abs(analyze(build_lm_mult4())["cpd"] - PUBLISHED_ROWS["lm"]["cpd"]) \
        / PUBLISHED_ROWS["lm"]["cpd"] < 0.10


def test_pipeline_improves_fmax():
    rep = pipelined_report()
    assert rep["fmax_mhz"] > rep["unpipelined_fmax_mhz"]
