"""Paper §V: functional correctness by exhaustive simulation over all 256
input combinations, for the proposed design and every re-implemented baseline,
in both evaluation modes (symbolic Boolean and INIT-truth-table)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    behavioral_mult4,
    build_acc_mult4,
    build_lm_mult4,
    build_proposed_mult4,
)

ALL_A = jnp.arange(16, dtype=jnp.uint8)[:, None] * jnp.ones((1, 16), jnp.uint8)
ALL_B = jnp.arange(16, dtype=jnp.uint8)[None, :] * jnp.ones((16, 1), jnp.uint8)
EXPECTED = (ALL_A.astype(jnp.uint32) * ALL_B.astype(jnp.uint32)).astype(jnp.uint8)

BUILDERS = {
    "proposed": build_proposed_mult4,
    "lm": build_lm_mult4,
    "acc_ullah": build_acc_mult4,
}


@pytest.mark.parametrize("design", sorted(BUILDERS))
@pytest.mark.parametrize("mode", ["direct", "init"])
def test_exhaustive_256(design, mode):
    netlist = BUILDERS[design]()
    got = netlist(ALL_A, ALL_B, mode=mode)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(EXPECTED))


def test_behavioral():
    np.testing.assert_array_equal(
        np.asarray(behavioral_mult4(ALL_A, ALL_B)), np.asarray(EXPECTED)
    )


def test_modes_agree_on_random_tensors():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(0, 16, size=(3, 7, 5), dtype=np.uint8))
    b = jnp.asarray(rng.integers(0, 16, size=(3, 7, 5), dtype=np.uint8))
    nl = build_proposed_mult4()
    np.testing.assert_array_equal(
        np.asarray(nl(a, b, mode="direct")), np.asarray(nl(a, b, mode="init"))
    )


def test_paper_lut1_init_matches_printed_value():
    nl = build_proposed_mult4()
    assert nl.init_table()["LUT1"] == 0x78887888A0A0A0A0


def test_dual_output_structure_matches_paper():
    # "three dual-output LUTs (LUTs 1, 5, and 7) and eight single-output LUTs"
    nl = build_proposed_mult4()
    duals = [c.name for c in nl.cells if hasattr(c, "is_dual") and c.is_dual]
    assert duals == ["LUT1", "LUT5", "LUT7"]
    assert nl.lut_count() == 11
