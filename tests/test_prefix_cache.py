"""Shared-prefix KV page reuse: refcounted content-addressed pool,
copy-on-write discipline, sentinel table hygiene, and the allocator/
scheduler bugfix batch (see serving/kv_pages.py module docstring)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import Runtime, ServingConfig, get_config
from repro.models.attention import attention_core
from repro.serving.api import poisson_trace, run_trace, shared_prefix_trace
from repro.serving.engine import InferenceEngine
from repro.serving.kv_pages import (
    ContinuousKVCache,
    PagedKVCacheManager,
    init_paged_attn_cache,
    paged_read,
    paged_write,
)
from repro.serving.scheduler import (
    CANCELLED,
    Request,
    Scheduler,
    ShedError,
    TIMEOUT,
)


SV = ServingConfig(layout="paged", max_batch=2, page_size=4, num_pages=8,
                   max_ctx=16)


def _req(rid, prompt, max_new=4, arrival=0.0):
    return Request(rid=rid, prompt=np.asarray(prompt, np.int32),
                   max_new=max_new, arrival=arrival)


# ----------------------------------------------------------- page manager --
def test_admit_request_shares_pages_and_refcounts():
    kv = PagedKVCacheManager(SV)
    tokens = np.arange(12, dtype=np.int32)
    assert kv.admit_request(0, tokens, 13) == 0     # nothing indexed yet
    kv.register_upto(0, tokens, 12)                 # pages 0..2 full
    donor = list(kv.pages[0])

    # same prefix: full pages are shared, capped below the full length
    hit = kv.admit_request(1, tokens, 13)
    assert hit == 8                                 # (12-1)//4 = 2 pages
    assert kv.pages[1][:2] == donor[:2]
    assert kv.refcount[donor[0]] == 2 and kv.refcount[donor[1]] == 2
    assert kv.pages[1][2] not in donor              # COW: fresh, not shared

    # diverging prefix stops at the divergence page (smaller allocation:
    # rid 0/1 already hold 6 of the 8 pool pages)
    other = tokens.copy()
    other[5] = 99
    assert kv.admit_request(2, other, 5) == 4
    kv.release(2)


def test_admission_miss_leaves_no_holds_or_counters():
    """A queue head blocked on capacity retries every step: failed
    admissions must not bump hit counters or churn warm-pool LRU order."""
    kv = PagedKVCacheManager(SV)
    tokens = np.arange(16, dtype=np.int32)
    assert kv.admit_request(0, tokens, 16) == 0     # 4 pages
    kv.register_upto(0, tokens, 16)
    assert kv.admit_request(1, 100 + np.arange(16, dtype=np.int32), 16) == 0
    lookups, hits = kv.n_lookups, kv.n_hit_tokens
    warm_before = list(kv.warm)
    # pool exhausted (8/8 in use): same-prefix admission must fail cleanly
    assert kv.admit_request(2, tokens, 16) is None
    assert 2 not in kv.pages and 2 not in kv._chain
    assert kv.n_lookups == lookups and kv.n_hit_tokens == hits
    assert list(kv.warm) == warm_before
    assert all(c == 1 for c in kv.refcount.values())


def test_release_keeps_registered_pages_warm_and_hittable():
    kv = PagedKVCacheManager(SV)
    tokens = np.arange(9, dtype=np.int32)
    assert kv.admit_request(0, tokens, 9) == 0
    kv.register_upto(0, tokens, 9)                  # 2 full pages indexed
    pages = list(kv.pages[0])
    kv.release(0)
    assert kv.available == SV.num_pages             # warm pages still free
    assert kv.in_use == 0
    # resubmission hits the warm pages with the same physical ids
    assert kv.admit_request(1, tokens, 9) == 8
    assert kv.pages[1][:2] == pages[:2]
    kv.release(1)

    # prefix_lru=off forgets content at release
    sv = ServingConfig(layout="paged", max_batch=2, page_size=4, num_pages=8,
                       max_ctx=16, prefix_lru=False)
    kv = PagedKVCacheManager(sv)
    assert kv.admit_request(0, tokens, 9) == 0
    kv.register_upto(0, tokens, 9)
    kv.release(0)
    assert kv.admit_request(1, tokens, 9) == 0
    assert not kv.index and not kv.page_hash


def test_warm_pages_evict_lru_when_blanks_run_dry():
    kv = PagedKVCacheManager(SV)
    a, b = np.arange(16, dtype=np.int32), 100 + np.arange(16, dtype=np.int32)
    assert kv.admit_request(0, a, 16) == 0          # 4 pages each
    assert kv.admit_request(1, b, 16) == 0
    kv.register_upto(0, a, 16)
    kv.register_upto(1, b, 16)
    kv.release(0)                                   # a's pages: oldest warm
    kv.release(1)
    assert kv.available == 8 and len(kv.index) == 8
    # a fresh full-pool request must evict — LRU order takes a's pages first
    assert kv.ensure(2, 16)
    assert kv.n_evictions == 4
    kv.release(2)
    assert kv.admit_request(3, a, 16) == 0          # a evicted...
    kv.release(3)
    assert kv.admit_request(4, b, 16) > 0           # ...b survived
    kv.release(4)


def test_zero_token_semantics_unified():
    """Bugfix: pages_for(0) returned 1 (paged) vs 0 (contiguous)."""
    assert PagedKVCacheManager(SV).pages_for(0) == 0
    assert ContinuousKVCache(SV).pages_for(0) == 0


def test_submit_error_is_layout_aware():
    """Bugfix: the capacity error printed page-pool numbers for the
    contiguous layout, where pages are meaningless."""
    big = _req(0, np.arange(64), max_new=64)
    with pytest.raises(ValueError, match=r"pool=8 pages"):
        Scheduler(PagedKVCacheManager(SV), 2).submit(big)
    with pytest.raises(ValueError) as ei:
        Scheduler(ContinuousKVCache(SV), 2).submit(big)
    assert "pages" not in str(ei.value)


def test_table_row_sentinel_for_unused_slots():
    """Bugfix: zero-filled table rows aliased physical page 0."""
    kv = PagedKVCacheManager(SV)
    kv.ensure(0, 5)
    row = kv.table_row(0)
    assert list(row[:2]) == kv.pages[0]
    assert (row[2:] == SV.num_pages).all()          # sentinel, not page 0


def test_poisoned_page0_cannot_leak_through_dead_table_slots():
    """Regression: a request whose table never references page 0 must not
    gather page-0 bytes through its unused (sentinel) slots — a NaN in a
    recycled page used to poison the PV contraction via 0 * NaN."""
    cfg = get_config("qwen2-0.5b").reduced()
    rt = Runtime(cache_dtype="bfloat16", aligned_decode=False)
    kv = PagedKVCacheManager(SV)
    kv.ensure(0, 8)                     # rid 0 owns pages 0..1
    kv.ensure(1, 8)                     # rid 1 owns pages 2..3
    cache = init_paged_attn_cache(cfg, rt, 1, SV)
    cache = dict(cache, tbl=jnp.asarray(kv.table_row(1))[None])

    n = 6
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.standard_normal((1, n, cfg.n_kv_heads, cfg.hd)),
                    jnp.bfloat16)
    pos = jnp.arange(n, dtype=jnp.int32)[None]
    cache = paged_write(cache, k, k, pos)
    q = jnp.asarray(rng.standard_normal((1, 1, cfg.n_heads, cfg.hd)),
                    jnp.bfloat16)
    last = jnp.asarray([n - 1], jnp.int32)

    def decode_out(c):
        kf, vf, kpos = paged_read(c, last)
        return np.asarray(attention_core(
            q, kf, vf, q_positions=last[:, None], k_positions=kpos,
            window=0, impl="full", chunk_q=512), np.float32)

    clean = decode_out(cache)
    poisoned = dict(cache, k=cache["k"].at[0].set(jnp.nan),
                    v=cache["v"].at[0].set(jnp.nan))
    out = decode_out(poisoned)
    assert np.isfinite(out).all()
    np.testing.assert_array_equal(clean, out)


# ------------------------------------------------- allocator property test --
def _run_sim(trace_spec, num_pages, max_new, events=(), max_queue=0):
    """Drive submit/step/preempt/finish through the real Scheduler+manager
    (model replaced by a deterministic token stream), asserting allocator
    invariants after every event.  `events` injects request-lifecycle
    hazards — ("cancel", k) aborts the k-th live request, ("expire", k)
    backdates its deadline so the step-boundary sweep retires it — and
    `max_queue` bounds admission so oversubscribed traces shed.  Structural
    invariants come from the shared checkers (`kv.check_invariants` /
    `sched.check_invariants`, the same ones the chaos harness asserts);
    the sim adds the write-discipline checks only it can make (it knows
    which page every token lands in)."""
    sv = ServingConfig(layout="paged", max_batch=2, page_size=4,
                       num_pages=num_pages, max_ctx=16, max_queue=max_queue)
    kv = PagedKVCacheManager(sv)
    sched = Scheduler(kv, max_batch=2, max_queue=max_queue)
    ps = sv.page_size
    bases = [np.arange(16, dtype=np.int32),
             1000 + np.arange(16, dtype=np.int32)]

    def check():
        kv.check_invariants()
        sched.check_invariants()

    def write(req, position):
        # COW discipline: the page a position lands in is exclusively ours
        # and not yet registered (registration == sealed/immutable)
        page = kv.pages[req.rid][position // ps]
        assert kv.refcount[page] == 1, "write into a shared page"
        assert page not in kv.page_hash, "write into a sealed page"

    rid, n_shed = 0, 0
    for arrival, base_i, L in trace_spec:
        try:
            sched.submit(_req(rid, bases[base_i][:L], max_new=max_new,
                              arrival=float(arrival)))
        except ShedError:
            n_shed += 1
            assert rid not in kv.pages        # shed before holding anything
        rid += 1
    if max_queue:
        assert len(sched.waiting) <= max_queue
    ev = list(events)
    now, guard = 0.0, 0
    while not sched.idle:
        guard += 1
        assert guard < 500
        if ev:
            kind, k = ev.pop(0)
            live = sorted({r.rid for r in sched.waiting} | set(sched.running))
            target = live[k % len(live)]
            if kind == "cancel":
                retired = sched.cancel(target, now)
                assert retired is not None and retired.outcome == CANCELLED
                assert target not in kv.pages, "cancel leaked pages"
            else:                               # backdate: expires this step
                req = sched.running.get(target) or next(
                    r for r in sched.waiting if r.rid == target)
                req.deadline = now
            check()
        for req in sched.expire(now):
            assert req.outcome == TIMEOUT
            assert req.rid not in kv.pages, "expiry leaked pages"
        check()
        for req in sched.admit(now):
            L = len(req.prefix)
            for p in range(req.n_cached, L):         # tail prefill writes
                write(req, p)
            req.n_cached = L
            kv.register_upto(req.rid, req.prefix, L)
            req.tokens.append(int(req.prefix[-1]) + 1)
            check()
        sched.ensure_decode()
        check()
        for req in list(sched.batch()):
            write(req, req.n_cached)                 # decode write
            req.n_cached += 1
            req.tokens.append(req.tokens[-1] + 1)
            if req.n_cached % ps == 0:
                kv.register_upto(req.rid, req.prefix, req.n_cached)
            check()
            if req.done:
                sched.finish(req, now)
                check()
        now += 1.0
    assert kv.in_use == 0, "drained scheduler left pages held"


@given(st.lists(
    st.sampled_from([(a, b, L)
                     for a in (0, 1, 2) for b in (0, 1)
                     for L in (3, 5, 8, 10)]),
    min_size=1, max_size=6),
    st.sampled_from([4, 6, 8]))
@settings(max_examples=25, deadline=None)
def test_allocator_invariants_under_random_traces(spec, num_pages):
    _run_sim(spec, num_pages, max_new=4)


@given(st.lists(
    st.sampled_from([(a, b, L)
                     for a in (0, 1, 2) for b in (0, 1)
                     for L in (3, 5, 8, 10)]),
    min_size=2, max_size=8),
    st.sampled_from([4, 6, 8]),
    st.lists(st.tuples(st.sampled_from(["cancel", "expire"]),
                       st.integers(0, 7)), max_size=6),
    st.sampled_from([0, 2, 3]))
@settings(max_examples=25, deadline=None)
def test_allocator_invariants_under_lifecycle_events(spec, num_pages,
                                                     events, max_queue):
    """Hardening: cancels, deadline expiries, and bounded-queue shedding
    interleaved with admission/preemption/finish must preserve every
    allocator invariant and leak no pages."""
    _run_sim(spec, num_pages, max_new=4, events=events, max_queue=max_queue)


# ------------------------------------------------------------- engine e2e --
@pytest.fixture(scope="module")
def reduced_cfg():
    return get_config("qwen2-0.5b").reduced()


def _engine(cfg, *, prefix_cache, num_pages=32, page_size=8, max_ctx=64,
            layout="paged"):
    rt = Runtime(quant_backend="float", cache_dtype="bfloat16", remat="none",
                 loss_chunk=0)
    sv = ServingConfig(layout=layout, max_batch=2, page_size=page_size,
                       num_pages=num_pages, max_ctx=max_ctx,
                       prefix_cache=prefix_cache)
    return InferenceEngine(cfg, rt, sv, seed=0)


def test_shared_prefix_hits_are_bit_identical_and_profitable(reduced_cfg):
    """Acceptance: with prefix_cache=on a shared-system-prompt trace decodes
    token-identically to the cold run, with hit rate > 0.5 and measurably
    fewer prefilled tokens."""
    trace = shared_prefix_trace(6, 1.0, 16, [8], [4], reduced_cfg.vocab,
                                seed=3)
    s_on, fin_on = run_trace(_engine(reduced_cfg, prefix_cache=True), trace)
    s_off, fin_off = run_trace(_engine(reduced_cfg, prefix_cache=False),
                               trace)
    assert [r.tokens for r in fin_on] == [r.tokens for r in fin_off]
    assert s_on["prefix_hit_rate"] > 0.5
    assert s_on["tokens_prefilled_saved"] > 0
    assert s_on["prefill_tokens"] < s_off["prefill_tokens"]
    assert s_off["tokens_prefilled_saved"] == 0


def test_shared_prefix_matches_contiguous(reduced_cfg):
    """Cache-hit prefills must agree with the contiguous layout too (the
    second cold reference of the compare harness)."""
    trace = shared_prefix_trace(4, 1.0, 16, [8], [4], reduced_cfg.vocab,
                                seed=11)
    _, fin_p = run_trace(_engine(reduced_cfg, prefix_cache=True), trace)
    _, fin_c = run_trace(_engine(reduced_cfg, prefix_cache=False,
                                 layout="contiguous"), trace)
    assert [r.tokens for r in fin_p] == [r.tokens for r in fin_c]


def test_preempt_resume_reprefills_only_uncached_suffix(reduced_cfg):
    """Bugfix: a preempted victim whose prefix pages survive in the warm
    pool re-admits at its hit length instead of re-prefilling everything."""
    rt = Runtime(quant_backend="float", cache_dtype="bfloat16", remat="none",
                 loss_chunk=0)
    sv = ServingConfig(layout="paged", max_batch=2, page_size=4, num_pages=6,
                       max_ctx=16)
    engine = InferenceEngine(reduced_cfg, rt, sv, seed=0)
    trace = poisson_trace(4, 2.0, [8], [8], reduced_cfg.vocab, seed=9)
    stats, fin = run_trace(engine, trace)
    assert stats["requests_finished"] == 4
    assert stats["requests_preempted"] >= 1
    assert stats["tokens_prefilled_saved"] > 0      # resume hit the cache
    # identical tokens vs an unconstrained run (no preemption, no resume)
    _, fin_big = run_trace(
        _engine(reduced_cfg, prefix_cache=True, page_size=8, max_ctx=32),
        trace)
    assert [r.tokens for r in fin] == [r.tokens for r in fin_big]
