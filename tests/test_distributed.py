"""Distribution-layer tests on 8 fake host devices (subprocess: the device
count must be fixed before jax initializes, so each test execs a script).

Covers: DP x TP train-step numerical equivalence vs single device, MoE
shard_map path vs local path, pipeline parallelism, elastic checkpoint
restore across mesh shapes, and dry-run machinery on a small mesh.
"""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_fake_devices(script: str, n: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n} "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True,
        text=True, timeout=timeout, cwd=REPO,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_dp_tp_train_step_matches_single_device():
    """Global loss/grads on a (2,4) mesh == single-device values."""
    run_fake_devices(r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, Runtime
from repro.distributed.sharding import (
    make_param_shardings, mesh_context, specs_to_shardings)
from repro.launch.mesh import make_mesh
from repro.models import init_model, lm_loss

cfg = get_config("qwen3-4b").reduced(n_layers=2, d_model=64, n_heads=4,
                                     n_kv_heads=2, d_ff=128, vocab=256)
rt = Runtime(loss_chunk=0, compute_dtype="float32", quant_backend="float")
params = init_model(jax.random.PRNGKey(0), cfg)
toks = jax.random.randint(jax.random.PRNGKey(1), (8, 33), 0, cfg.vocab)

l_single = float(lm_loss(params, toks, cfg, rt)[0])

mesh = make_mesh((2, 4), ("data", "model"))
with mesh_context(mesh):
    specs = make_param_shardings(params, mesh)
    p_sharded = jax.device_put(params, specs_to_shardings(specs, mesh))
    loss_fn = jax.jit(lambda p, t: lm_loss(p, t, cfg, rt)[0])
    l_mesh = float(loss_fn(p_sharded, toks))
np.testing.assert_allclose(l_mesh, l_single, rtol=1e-5)
print("OK", l_single, l_mesh)
""")


def test_moe_shard_map_matches_local():
    """MoE through shard_map (EP over model + FSDP gather) == local path."""
    run_fake_devices(r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, Runtime
from repro.distributed.sharding import mesh_context
from repro.launch.mesh import make_mesh
from repro.models.moe import apply_moe, init_moe

cfg = get_config("arctic-480b").reduced(
    n_experts=8, d_model=64, d_ff_expert=64, capacity_factor=64.0)
rt = Runtime(quant_backend="float", compute_dtype="float32")
p = init_moe(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 64))

y_local, aux_local = apply_moe(p, x, cfg, rt)

mesh = make_mesh((2, 4), ("data", "model"))
with mesh_context(mesh):
    fn = jax.jit(lambda p, x: apply_moe(p, x, cfg, rt))
    y_mesh, aux_mesh = fn(p, x)
np.testing.assert_allclose(np.asarray(y_mesh), np.asarray(y_local),
                           rtol=2e-4, atol=2e-5)
# aux is a per-data-shard estimator (Switch-style): close, not identical
np.testing.assert_allclose(float(aux_mesh), float(aux_local), rtol=0.1)
print("OK")
""")


def test_moe_shard_map_gradients_match_local():
    run_fake_devices(r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, Runtime
from repro.distributed.sharding import mesh_context
from repro.launch.mesh import make_mesh
from repro.models.moe import apply_moe, init_moe

cfg = get_config("llama4-maverick-400b-a17b").reduced(
    n_experts=8, d_model=64, d_ff_expert=64, capacity_factor=64.0)
rt = Runtime(quant_backend="float", compute_dtype="float32")
p = init_moe(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 64))

def loss(p, x):
    # y-only loss: the aux estimator is per-shard (see matches_local test)
    y, aux = apply_moe(p, x, cfg, rt)
    return jnp.sum(y ** 2)

g_local = jax.grad(loss)(p, x)
mesh = make_mesh((2, 4), ("data", "model"))
with mesh_context(mesh):
    g_mesh = jax.jit(jax.grad(loss))(p, x)
for a, b in zip(jax.tree.leaves(g_local), jax.tree.leaves(g_mesh)):
    np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                               rtol=5e-4, atol=1e-5)
print("OK")
""")


def test_pipeline_parallel_stages():
    """GPipe pipeline over a 4-stage mesh == sequential application."""
    run_fake_devices(r"""
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import pipeline_apply
from repro.launch.mesh import make_mesh

mesh = make_mesh((4,), ("stage",))
n_stages, n_micro, mb, d = 4, 8, 4, 16
ws = jax.random.normal(jax.random.PRNGKey(0), (n_stages, d, d)) * 0.3
x = jax.random.normal(jax.random.PRNGKey(1), (n_micro * mb, d))

def stage_fn(w, xb):
    return jnp.tanh(xb @ w)

y_ref = x
for s in range(n_stages):
    y_ref = stage_fn(ws[s], y_ref)

y = pipeline_apply(stage_fn, ws, x, mesh=mesh, n_micro=n_micro)
np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-5,
                           atol=2e-6)
print("OK")
""")


def test_elastic_checkpoint_across_mesh_shapes(tmp_path):
    """Save params sharded on (2,4); restore onto (4,2) and single device."""
    run_fake_devices(rf"""
import jax, jax.numpy as jnp, numpy as np
from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.distributed.sharding import (
    make_param_shardings, mesh_context, specs_to_shardings)
from repro.launch.mesh import make_mesh
from repro.models import init_model

cfg = get_config("qwen3-4b").reduced(n_layers=2, d_model=64, n_heads=4,
                                     n_kv_heads=2, d_ff=128, vocab=256)
params = init_model(jax.random.PRNGKey(0), cfg)
mesh_a = make_mesh((2, 4), ("data", "model"))
sh_a = specs_to_shardings(make_param_shardings(params, mesh_a), mesh_a)
p_a = jax.device_put(params, sh_a)

mgr = CheckpointManager(r"{tmp_path}", save_every=1)
mgr.maybe_save(1, p_a, force=True)

mesh_b = make_mesh((4, 2), ("data", "model"))
sh_b = specs_to_shardings(make_param_shardings(params, mesh_b), mesh_b)
p_b, step = mgr.restore(params, shardings=sh_b)
for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p_b)):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32))
p_c, _ = mgr.restore(params)          # plain single-device restore
print("OK", step)
""")


def test_dryrun_machinery_small_mesh():
    """The dry-run entry point end-to-end on a 2x4 fake mesh."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "qwen2-0.5b", "--shape", "decode_32k",
         "--devices", "8", "--mesh", "2,4", "--out", "/tmp/dryrun_pytest"],
        env=env, capture_output=True, text=True, timeout=900, cwd=REPO,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    rep = json.load(
        open("/tmp/dryrun_pytest/qwen2-0.5b__decode_32k__pod1.json"))
    assert rep["status"] == "ok"
    assert rep["memory"]["total_hbm_bytes"] > 0
    assert rep["roofline"]["bound"] in ("compute", "memory", "collective")


def test_train_preemption_restart_bitexact(tmp_path):
    """Kill training mid-run; resume must continue from the checkpoint and
    reach the identical final state as an uninterrupted run."""
    script = rf"""
import numpy as np, jax
from repro.launch.train import train

state1, h1 = train("qwen2-0.5b", steps=6, batch=2, seq=32,
                   ckpt_dir=r"{tmp_path}/a", save_every=3, seed=7)

# interrupted run: first 3 steps, then a fresh process restores and finishes
state2a, _ = train("qwen2-0.5b", steps=3, batch=2, seq=32,
                   ckpt_dir=r"{tmp_path}/b", save_every=3, seed=7)
state2b, h2 = train("qwen2-0.5b", steps=6, batch=2, seq=32,
                    ckpt_dir=r"{tmp_path}/b", save_every=3, seed=7)

for a, b in zip(jax.tree.leaves(state1["params"]),
                jax.tree.leaves(state2b["params"])):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=1e-6)
print("OK")
"""
    run_fake_devices(script, n=1, timeout=900)
