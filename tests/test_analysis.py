"""Golden-fixture suite for the contract analyzer (``repro.analysis``).

Each rule gets at least one known-bad snippet that must fire and one clean
twin that must not; plus framework tests for suppression markers, baseline
add/remove semantics, fingerprint stability, and the no-JAX-import
guarantee (the lint job must run before jax is even importable).

The snippets are *fixtures*, not live code — they model the idioms the
rules were calibrated against (engine step attrs, kernel wrappers, the
metrics registry call shape).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

from repro.analysis import (
    all_rules,
    analyze_paths,
    analyze_source,
    gate,
    load_baseline,
    write_baseline,
)
from repro.analysis.cli import main as cli_main

REPO_SRC = str(Path(__file__).resolve().parent.parent / "src")


def run(src: str, rel: str = "src/repro/serving/mod.py", only: str = None):
    rules = all_rules()
    if only is not None:
        rules = {only: rules[only]}
    return analyze_source(textwrap.dedent(src), rel, rules)


def rules_of(findings):
    return [f.rule for f in findings]


# ---------------------------------------------------- recompile-hazard ----
def test_recompile_hazard_jit_and_invoke_fires():
    bad = """
    import jax
    def f(x):
        return jax.jit(lambda y: y + 1)(x)
    """
    fs = run(bad, only="recompile-hazard")
    assert rules_of(fs) == ["recompile-hazard"]
    assert "fresh trace + compile" in fs[0].message


def test_recompile_hazard_jit_in_loop_fires():
    bad = """
    import jax
    def f(fns, x):
        for fn in fns:
            g = jax.jit(fn)
            x = g(x)
        return x
    """
    fs = run(bad, only="recompile-hazard")
    assert rules_of(fs) == ["recompile-hazard"]
    assert "inside a loop" in fs[0].message


def test_recompile_hazard_host_scalar_into_step_jit_fires():
    bad = """
    class Engine:
        def go(self, params, batch):
            toks, caches = self._decode(params, len(batch), self.caches)
            return toks
    """
    fs = run(bad, only="recompile-hazard")
    assert rules_of(fs) == ["recompile-hazard"]
    assert "'self._decode'" in fs[0].message and "arg 1" in fs[0].message


def test_recompile_hazard_clean_twin():
    # device arrays into the step jit, donated cache position, and a
    # module-scope jit with the scalar declared static: all sanctioned
    clean = """
    import jax
    import jax.numpy as jnp

    step = jax.jit(lambda x, n: x, static_argnums=(1,))

    class Engine:
        def go(self, params, toks, batch):
            out, self.caches = self._decode(params, jnp.asarray(toks),
                                            self.caches)
            return step(out, len(batch))
    """
    assert run(clean, only="recompile-hazard") == []


def test_recompile_hazard_static_argnames_kwarg_clean():
    src = """
    import jax
    f = jax.jit(lambda x, n=1: x, static_argnames=("n",))
    def g(x, batch):
        return f(x, n=len(batch))
    """
    assert run(src, only="recompile-hazard") == []


# ------------------------------------------- donation-use-after-transfer ----
def test_donation_read_after_step_attr_fires():
    bad = """
    class Engine:
        def go(self, params, toks):
            out, new_caches = self._decode(params, toks, self.caches)
            stale = self.caches[0]
            return out, stale
    """
    fs = run(bad, only="donation-use-after-transfer")
    assert rules_of(fs) == ["donation-use-after-transfer"]
    assert "'self.caches'" in fs[0].message


def test_donation_rebind_from_result_clean():
    clean = """
    class Engine:
        def go(self, params, toks):
            out, self.caches = self._decode(params, toks, self.caches)
            fine = self.caches[0]
            return out, fine
    """
    assert run(clean, only="donation-use-after-transfer") == []


def test_donation_local_jit_donate_argnums_fires():
    bad = """
    import jax
    step = jax.jit(lambda buf: buf * 2, donate_argnums=(0,))
    def go(buf):
        y = step(buf)
        return buf + 1
    """
    fs = run(bad, only="donation-use-after-transfer")
    assert rules_of(fs) == ["donation-use-after-transfer"]
    assert "'buf'" in fs[0].message


def test_donation_one_finding_per_donation_site():
    bad = """
    class Engine:
        def go(self, params, toks):
            out, fresh = self._decode(params, toks, self.caches)
            a = self.caches[0]
            b = self.caches[1]
            return out, a, b
    """
    # dead buffer read twice -> flag the first read only (one finding per
    # donation), not a cascade down the function
    fs = run(bad, only="donation-use-after-transfer")
    assert len(fs) == 1


# ------------------------------------------------- host-sync-in-hot-path ----
def test_host_sync_in_hot_fn_fires():
    bad = """
    import numpy as np
    class Engine:
        def _decode_batch(self, batch):
            logits = self.run(batch)
            probs = np.asarray(logits)
            return probs
    """
    fs = run(bad, only="host-sync-in-hot-path")
    assert rules_of(fs) == ["host-sync-in-hot-path"]
    assert "_decode_batch" in fs[0].message


def test_host_sync_item_and_float_fire():
    bad = """
    class Engine:
        def _step_decode(self, x):
            a = x.item()
            b = float(x)
            return a + b
    """
    fs = run(bad, only="host-sync-in-hot-path")
    assert len(fs) == 2


def test_host_sync_cold_fn_clean():
    # same syncs outside a hot-path function: not the rule's business
    clean = """
    import numpy as np
    class Engine:
        def snapshot(self, x):
            return np.asarray(x)
    """
    assert run(clean, only="host-sync-in-hot-path") == []


def test_host_sync_host_values_clean():
    # len/int/np-constructed values are already host: no transfer to flag
    clean = """
    import numpy as np
    class Engine:
        def _decode_batch(self, batch):
            n = len(batch)
            m = int(n)
            z = np.asarray([1, 2, 3])
            return m + z[0]
    """
    assert run(clean, only="host-sync-in-hot-path") == []


def test_host_sync_result_is_host_downstream():
    # the engine idiom: ONE flagged readback, then int() over the now-host
    # array must NOT cascade into more findings
    bad = """
    import numpy as np
    class Engine:
        def _decode_batch(self, batch, nxt):
            nxt = np.asarray(nxt)
            for i, req in enumerate(batch):
                req.tokens.append(int(nxt[i]))
            return batch
    """
    fs = run(bad, only="host-sync-in-hot-path")
    assert len(fs) == 1
    assert fs[0].text == "nxt = np.asarray(nxt)"


# ------------------------------------------------ pallas-kernel-hygiene ----
KERNEL_REL = "src/repro/kernels/fixture_kernel.py"


def test_kernel_traced_branch_fires():
    bad = """
    def _kernel(x_ref, o_ref):
        v = x_ref[0]
        if v > 0:
            o_ref[0] = v
    """
    fs = run(bad, rel=KERNEL_REL, only="pallas-kernel-hygiene")
    assert any("traced value inside kernel body" in f.message for f in fs)


def test_kernel_pl_when_clean():
    clean = """
    from jax.experimental import pallas as pl
    import jax.numpy as jnp

    def _kernel(x_ref, o_ref):
        k = pl.program_id(0)

        @pl.when(k == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += jnp.where(x_ref[...] > 0, x_ref[...], 0)
    """
    assert run(clean, rel=KERNEL_REL, only="pallas-kernel-hygiene") == []


def test_wrapper_missing_divisibility_assert_fires():
    bad = """
    import jax
    from jax.experimental import pallas as pl
    from .dispatch import default_interpret

    def launch(x, bm, interpret=None):
        return pl.pallas_call(
            _kernel,
            grid=(x.shape[0] // bm,),
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=default_interpret(interpret),
        )(x)
    """
    fs = run(bad, rel=KERNEL_REL, only="pallas-kernel-hygiene")
    assert any("divisibility" in f.message for f in fs)


def test_wrapper_hardcoded_interpret_fires():
    bad = """
    import jax
    from jax.experimental import pallas as pl

    def launch(x, bm):
        assert x.shape[0] % bm == 0, (x.shape, bm)
        return pl.pallas_call(
            _kernel,
            grid=(x.shape[0] // bm,),
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=True,
        )(x)
    """
    fs = run(bad, rel=KERNEL_REL, only="pallas-kernel-hygiene")
    assert any("hardcodes interpret" in f.message for f in fs)


def test_wrapper_missing_interpret_kwarg_fires():
    bad = """
    import jax
    from jax.experimental import pallas as pl

    def launch(x, bm):
        assert x.shape[0] % bm == 0, (x.shape, bm)
        return pl.pallas_call(
            _kernel,
            grid=(x.shape[0] // bm,),
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        )(x)
    """
    fs = run(bad, rel=KERNEL_REL, only="pallas-kernel-hygiene")
    assert any("without interpret=" in f.message for f in fs)


def test_wrapper_clean_twin():
    clean = """
    import jax
    from jax.experimental import pallas as pl
    from .dispatch import default_interpret

    def launch(x, bm, interpret=None):
        assert x.shape[0] % bm == 0, (x.shape, bm)
        return pl.pallas_call(
            _kernel,
            grid=(x.shape[0] // bm,),
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=default_interpret(interpret),
        )(x)
    """
    assert run(clean, rel=KERNEL_REL, only="pallas-kernel-hygiene") == []


def test_backend_probe_in_kernel_file_fires_but_dispatch_exempt():
    src = """
    import jax
    INTERPRET = jax.default_backend() != "tpu"
    """
    fs = run(src, rel=KERNEL_REL, only="pallas-kernel-hygiene")
    assert any("backend dispatch decision" in f.message for f in fs)
    for exempt in ("ops.py", "dispatch.py", "autotune.py"):
        assert run(src, rel=f"src/repro/kernels/{exempt}",
                   only="pallas-kernel-hygiene") == []


# ---------------------------------------------- tolerance-claim-mismatch ----
TEST_REL = "tests/test_fixture.py"


def test_tolerance_claim_allclose_fires():
    bad = """
    import numpy as np
    def test_checkpoint_roundtrip():
        '''save/restore round-trips bit-identically.'''
        a, b = save_restore()
        np.testing.assert_allclose(a, b)
    """
    fs = run(bad, rel=TEST_REL, only="tolerance-claim-mismatch")
    assert rules_of(fs) == ["tolerance-claim-mismatch"]


def test_tolerance_claim_array_equal_clean():
    clean = """
    import numpy as np
    def test_checkpoint_roundtrip():
        '''save/restore round-trips bit-identically.'''
        a, b = save_restore()
        np.testing.assert_array_equal(a, b)
    """
    assert run(clean, rel=TEST_REL, only="tolerance-claim-mismatch") == []


def test_tolerance_no_exactness_claim_clean():
    clean = """
    import numpy as np
    def test_quant_error_small():
        '''quantized output stays close to float reference.'''
        a, b = compute()
        np.testing.assert_allclose(a, b, rtol=1e-5)
    """
    assert run(clean, rel=TEST_REL, only="tolerance-claim-mismatch") == []


def test_tolerance_rule_ignores_non_test_files():
    src = """
    import numpy as np
    def check_roundtrip_identical(a, b):
        np.testing.assert_allclose(a, b)
    """
    assert run(src, rel="src/repro/core/check.py",
               only="tolerance-claim-mismatch") == []


# ------------------------------------------------- metrics-label-hygiene ----
def test_metrics_open_label_value_fires():
    bad = """
    def record(m, rid):
        m.counter("requests_total", "reqs", rid=f"req-{rid}").inc()
    """
    fs = run(bad, only="metrics-label-hygiene")
    assert rules_of(fs) == ["metrics-label-hygiene"]
    assert "built at call time" in fs[0].message


def test_metrics_outcome_typo_fires():
    bad = """
    def record(m):
        m.counter("requests_total", "reqs", outcome="canceled").inc()
    """
    fs = run(bad, only="metrics-label-hygiene")
    assert rules_of(fs) == ["metrics-label-hygiene"]
    assert "'canceled'" in fs[0].message


def test_metrics_computed_name_fires():
    bad = """
    def record(m, op):
        m.counter(f"{op}_total", "per-op").inc()
    """
    fs = run(bad, only="metrics-label-hygiene")
    assert rules_of(fs) == ["metrics-label-hygiene"]
    assert "string literal" in fs[0].message


def test_metrics_splat_labels_fire():
    bad = """
    def record(m, labels):
        m.counter("requests_total", "reqs", **labels).inc()
    """
    fs = run(bad, only="metrics-label-hygiene")
    assert rules_of(fs) == ["metrics-label-hygiene"]


def test_metrics_closed_labels_clean():
    clean = """
    def record(m, outcome, mode):
        m.counter("requests_total", "reqs", outcome=outcome).inc()
        m.counter("requests_total", "reqs", outcome="timeout").inc()
        m.counter("dispatch_total", "d", mode=mode).inc()
        m.histogram("ttft_us", "ttft", buckets=[1000, 10000]).observe(5)
    """
    assert run(clean, only="metrics-label-hygiene") == []


def test_metrics_non_registry_counter_not_matched():
    # collections.Counter-ish .counter()/.histogram() calls don't have the
    # (name, help, **labels) two-leading-string shape: out of scope
    clean = """
    def tally(counts, key):
        counts.counter(key)
        counts.histogram(key, 5)
    """
    assert run(clean, only="metrics-label-hygiene") == []


# ----------------------------------------------------------- suppressions ----
def test_suppression_same_line():
    src = """
    import numpy as np
    class Engine:
        def _decode_batch(self, nxt):
            nxt = np.asarray(nxt)  # repro: ignore[host-sync-in-hot-path]
            return nxt
    """
    assert run(src, only="host-sync-in-hot-path") == []


def test_suppression_preceding_comment_line():
    src = """
    import numpy as np
    class Engine:
        def _decode_batch(self, nxt):
            # repro: ignore[host-sync-in-hot-path] sanctioned readback
            nxt = np.asarray(nxt)
            return nxt
    """
    assert run(src, only="host-sync-in-hot-path") == []


def test_suppression_bare_marker_suppresses_all_rules():
    src = """
    import numpy as np
    class Engine:
        def _decode_batch(self, nxt):
            nxt = np.asarray(nxt)  # repro: ignore
            return nxt
    """
    assert run(src, only="host-sync-in-hot-path") == []


def test_suppression_wrong_rule_does_not_suppress():
    src = """
    import numpy as np
    class Engine:
        def _decode_batch(self, nxt):
            nxt = np.asarray(nxt)  # repro: ignore[recompile-hazard]
            return nxt
    """
    fs = run(src, only="host-sync-in-hot-path")
    assert rules_of(fs) == ["host-sync-in-hot-path"]


def test_suppression_marker_in_string_does_not_suppress():
    # the marker is parsed from COMMENT tokens, not raw text
    src = '''
    import numpy as np
    class Engine:
        def _decode_batch(self, nxt):
            nxt = np.asarray(nxt); note = "# repro: ignore"
            return nxt, note
    '''
    fs = run(src, only="host-sync-in-hot-path")
    assert rules_of(fs) == ["host-sync-in-hot-path"]


# ------------------------------------------------- baseline + fingerprints ----
BAD_MODULE = textwrap.dedent("""
    import jax
    def f(x):
        return jax.jit(lambda y: y + 1)(x)
""")


def _write_tree(tmp_path, body=BAD_MODULE):
    pkg = tmp_path / "scratch"
    pkg.mkdir(exist_ok=True)
    (pkg / "mod.py").write_text(body)
    return pkg


def test_baseline_roundtrip_add_then_fix(tmp_path):
    pkg = _write_tree(tmp_path)
    bl_path = str(tmp_path / "baseline.json")

    findings = analyze_paths([str(pkg)], root=str(tmp_path))
    assert rules_of(findings) == ["recompile-hazard"]

    # accept into baseline -> gate reports nothing new
    write_baseline(bl_path, findings)
    baseline = load_baseline(bl_path)
    new, known, stale = gate(findings, baseline)
    assert new == [] and len(known) == 1 and stale == []
    assert baseline[findings[0].fingerprint]["justification"] \
        == "TODO: justify or fix"

    # fix the violation -> entry goes stale; rewrite prunes it
    _write_tree(tmp_path, "def f(x):\n    return x\n")
    findings2 = analyze_paths([str(pkg)], root=str(tmp_path))
    new, known, stale = gate(findings2, load_baseline(bl_path))
    assert findings2 == [] and new == [] and stale != []
    write_baseline(bl_path, findings2, old=baseline)
    assert load_baseline(bl_path) == {}


def test_baseline_new_violation_still_fails(tmp_path):
    pkg = _write_tree(tmp_path)
    bl_path = str(tmp_path / "baseline.json")
    write_baseline(bl_path, analyze_paths([str(pkg)], root=str(tmp_path)))

    # a second, different hazard appears: baseline must not mask it
    (pkg / "mod.py").write_text(BAD_MODULE + textwrap.dedent("""
        def g(fns, x):
            for fn in fns:
                x = jax.jit(fn)(x)
            return x
    """))
    findings = analyze_paths([str(pkg)], root=str(tmp_path))
    new, known, stale = gate(findings, load_baseline(bl_path))
    assert len(known) == 1 and len(new) >= 1 and stale == []


def test_baseline_preserves_justification_on_rewrite(tmp_path):
    pkg = _write_tree(tmp_path)
    bl_path = str(tmp_path / "baseline.json")
    findings = analyze_paths([str(pkg)], root=str(tmp_path))
    write_baseline(bl_path, findings)
    baseline = load_baseline(bl_path)
    fp = findings[0].fingerprint
    baseline[fp]["justification"] = "profiling probe, compiles once at boot"
    write_baseline(bl_path, findings, old=baseline)
    assert load_baseline(bl_path)[fp]["justification"] \
        == "profiling probe, compiles once at boot"


def test_fingerprints_stable_under_line_drift(tmp_path):
    pkg = _write_tree(tmp_path)
    fp1 = analyze_paths([str(pkg)], root=str(tmp_path))[0].fingerprint
    # unrelated lines above shift the finding down: fingerprint unchanged
    _write_tree(tmp_path, "import os\n\nX = 1\n" + BAD_MODULE)
    fp2 = analyze_paths([str(pkg)], root=str(tmp_path))[0].fingerprint
    assert fp1 == fp2


def test_fingerprints_disambiguate_identical_lines(tmp_path):
    body = BAD_MODULE + textwrap.dedent("""
        def g(x):
            return jax.jit(lambda y: y + 1)(x)
    """)
    pkg = _write_tree(tmp_path, body)
    fs = analyze_paths([str(pkg)], root=str(tmp_path))
    assert len(fs) == 2
    assert fs[0].fingerprint != fs[1].fingerprint
    assert fs[0].fingerprint.endswith("|0") and fs[1].fingerprint.endswith("|1")


def test_syntax_error_reported_as_finding(tmp_path):
    pkg = _write_tree(tmp_path, "def broken(:\n")
    fs = analyze_paths([str(pkg)], root=str(tmp_path))
    assert rules_of(fs) == ["syntax-error"]


# ---------------------------------------------------------------- CLI ----
def test_cli_exit_codes_and_json(tmp_path, capsys):
    pkg = _write_tree(tmp_path)
    bl_path = str(tmp_path / "baseline.json")

    # unbaselined violation -> exit 1, json report carries it
    rc = cli_main([str(pkg), "--format", "json"])
    report = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert report["counts"]["new"] == 1
    assert report["findings"][0]["rule"] == "recompile-hazard"

    # accept, then gate passes -> exit 0
    assert cli_main([str(pkg), "--baseline", bl_path,
                     "--write-baseline"]) == 0
    capsys.readouterr()
    rc = cli_main([str(pkg), "--baseline", bl_path, "--format", "json"])
    report = json.loads(capsys.readouterr().out)
    assert rc == 0
    assert report["counts"]["new"] == 0 and report["counts"]["baselined"] == 1


def test_cli_rules_filter_and_unknown_rule(tmp_path, capsys):
    pkg = _write_tree(tmp_path)
    rc = cli_main([str(pkg), "--rules", "metrics-label-hygiene"])
    capsys.readouterr()
    assert rc == 0                      # hazard rule filtered out
    try:
        cli_main([str(pkg), "--rules", "no-such-rule"])
    except SystemExit as e:
        assert "no-such-rule" in str(e.code)
    else:
        raise AssertionError("unknown rule must SystemExit")


def test_repo_gates_clean_against_committed_baseline(capsys):
    """The acceptance gate CI runs: src+tests vs analysis_baseline.json."""
    root = Path(__file__).resolve().parent.parent
    old = os.getcwd()
    os.chdir(root)
    try:
        rc = cli_main(["src", "tests", "--baseline",
                       "analysis_baseline.json", "--format", "json"])
        report = json.loads(capsys.readouterr().out)
    finally:
        os.chdir(old)
    assert rc == 0, report["new"]
    assert report["counts"]["stale_baseline"] == 0


def test_analyzer_does_not_import_jax(tmp_path):
    """The lint pass must run on a box with no working jax: a seeded
    recompile hazard is flagged from the AST alone, and importing/running
    the analyzer never pulls jax into the process."""
    bad = tmp_path / "scratch_fixture.py"
    bad.write_text(BAD_MODULE)
    probe = (
        "import sys, json\n"
        "from repro.analysis import analyze_paths\n"
        "fs = analyze_paths([sys.argv[1]])\n"
        "assert 'jax' not in sys.modules, 'analyzer imported jax'\n"
        "print(json.dumps([f.rule for f in fs]))\n"
    )
    env = dict(os.environ, PYTHONPATH=REPO_SRC)
    out = subprocess.run([sys.executable, "-c", probe, str(bad)],
                         capture_output=True, text=True, env=env)
    assert out.returncode == 0, out.stderr
    assert json.loads(out.stdout) == ["recompile-hazard"]
