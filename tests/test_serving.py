"""Continuous-batching serving engine: page allocator, scheduler policies
(admission ordering, exhaustion -> preemption -> resume, block-table reuse),
and bit-exactness of paged vs contiguous KV attention."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import Runtime, ServingConfig, get_config
from repro.models.attention import _cache_read, _cache_write, init_attn_cache
from repro.serving.api import poisson_trace, run_trace
from repro.serving.engine import InferenceEngine
from repro.serving.kv_pages import (
    PagedKVCacheManager,
    init_paged_attn_cache,
    paged_read,
    paged_write,
)
from repro.serving.scheduler import Request, Scheduler


SV = ServingConfig(layout="paged", max_batch=2, page_size=4, num_pages=8,
                   max_ctx=16)


def _req(rid, L=4, max_new=4, arrival=0.0):
    return Request(rid=rid, prompt=np.arange(L, dtype=np.int32),
                   max_new=max_new, arrival=arrival)


# ------------------------------------------------------------ page manager --
def test_page_manager_alloc_release_reuse():
    kv = PagedKVCacheManager(SV)
    assert kv.ensure(0, 6)            # 2 pages
    assert kv.ensure(1, 9)            # 3 pages
    assert kv.in_use == 5
    first = list(kv.table_row(0)[:2])
    # growth is incremental: +1 page for 12 tokens
    assert kv.ensure(0, 12) and kv.in_use == 6
    assert list(kv.table_row(0)[:2]) == first       # existing pages stable
    # exhaustion: 3 more pages don't exist
    assert not kv.ensure(2, 10)
    assert kv.in_use == 6                           # all-or-nothing
    # release -> the pages are reusable by a new sequence
    kv.release(0)
    assert kv.available == 5
    assert kv.ensure(2, 10)
    assert set(kv.table_row(2)[:3]) <= set(range(SV.num_pages))


def test_page_manager_respects_max_ctx():
    kv = PagedKVCacheManager(SV)
    assert not kv.ensure(0, SV.max_ctx + 1)
    assert not kv.fits_alone(SV.max_ctx + 1)


# --------------------------------------------------------------- scheduler --
def test_admission_fifo_order_and_slots():
    sched = Scheduler(PagedKVCacheManager(SV), max_batch=2)
    for rid in range(3):
        sched.submit(_req(rid))
    admitted = sched.admit(now=0.0)
    assert [r.rid for r in admitted] == [0, 1]      # FIFO
    assert [r.rid for r in sched.batch()] == [0, 1]  # slot order
    assert admitted[0].slot == 0 and admitted[1].slot == 1
    assert [r.rid for r in sched.waiting] == [2]
    # a future arrival is not admitted even with a free slot
    sched.finish(admitted[0], now=1.0)
    sched.submit(_req(3, arrival=99.0))
    admitted = sched.admit(now=1.0)
    assert [r.rid for r in admitted] == [2]
    assert admitted[0].slot == 0                    # freed slot reused


def test_exhaustion_preempts_latest_then_resumes():
    sv = ServingConfig(layout="paged", max_batch=2, page_size=4,
                       num_pages=4, max_ctx=16)
    sched = Scheduler(PagedKVCacheManager(sv), max_batch=2)
    a, b = _req(0, L=7, max_new=9), _req(1, L=4, max_new=4)
    sched.submit(a)
    sched.submit(b)
    assert len(sched.admit(now=0.0)) == 2           # 2 + 2 pages (prefix+1)
    a.n_cached, b.n_cached = 7, 4
    # a grows to 9, 11 cached tokens: needs a 3rd page -> pool dry -> the
    # latest-admitted request (b) is preempted back to the queue front
    a.n_cached = 11
    preempted = sched.ensure_decode()
    assert [r.rid for r in preempted] == [1]
    assert b.state == "waiting" and b.n_preempts == 1 and b.n_cached == 0
    assert sched.waiting[0] is b
    assert [r.rid for r in sched.batch()] == [0]
    # resume: once a finishes, b re-admits and re-allocates
    sched.finish(a, now=5.0)
    assert [r.rid for r in sched.admit(now=5.0)] == [1]
    assert sched.kv.ensure(1, 4)


def test_preemption_keeps_generated_prefix():
    sv = ServingConfig(layout="paged", max_batch=2, page_size=4,
                       num_pages=4, max_ctx=16)
    sched = Scheduler(PagedKVCacheManager(sv), max_batch=2)
    b = _req(1, L=4, max_new=8)
    sched.submit(_req(0, L=8, max_new=8))
    sched.submit(b)
    sched.admit(now=0.0)
    b.tokens.extend([7, 8, 9])
    sched.running[0].n_cached = 12
    sched.ensure_decode()
    # recompute-style preemption: prefix carries generated tokens for resume
    assert list(b.prefix) == [0, 1, 2, 3, 7, 8, 9]


# ------------------------------------------- paged vs contiguous KV caches --
@pytest.mark.parametrize("cache_dtype", ["bfloat16", "int8", "int4"])
def test_paged_read_bit_identical_to_contiguous(cache_dtype):
    cfg = get_config("qwen2-0.5b").reduced()
    rt = Runtime(cache_dtype=cache_dtype, aligned_decode=False)
    sv = ServingConfig(layout="paged", max_batch=2, page_size=4,
                       num_pages=16, max_ctx=16)
    B, n = 2, 10
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.standard_normal((B, n, cfg.n_kv_heads, cfg.hd)),
                    jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((B, n, cfg.n_kv_heads, cfg.hd)),
                    jnp.bfloat16)
    pos = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (B, n))

    cont = init_attn_cache(cfg, rt, B, sv.max_ctx)
    cont = _cache_write(cont, k, v, pos)
    kc, vc = _cache_read(cont)

    # non-trivial block tables: permuted page order per row
    kv_mgr = PagedKVCacheManager(sv)
    kv_mgr.ensure(99, 5)               # burn pages so tables aren't 0,1,2..
    kv_mgr.ensure(0, n)
    kv_mgr.release(99)
    kv_mgr.ensure(1, n)
    tbl = jnp.asarray(np.stack([kv_mgr.table_row(0), kv_mgr.table_row(1)]))
    paged = init_paged_attn_cache(cfg, rt, B, sv)
    paged = dict(paged, tbl=tbl)
    paged = paged_write(paged, k, v, pos)
    kp, vp, kpos = paged_read(paged, jnp.full((B,), n - 1, jnp.int32))

    valid = np.asarray(kpos) >= 0
    assert valid[:, :n].all() and not valid[:, n:].any()
    np.testing.assert_array_equal(np.asarray(kc, np.float32)[:, :n],
                                  np.asarray(kp, np.float32)[:, :n])
    np.testing.assert_array_equal(np.asarray(vc, np.float32)[:, :n],
                                  np.asarray(vp, np.float32)[:, :n])
    np.testing.assert_array_equal(np.asarray(cont["kpos"])[:, :n],
                                  np.asarray(kpos)[:, :n])


def test_negative_positions_are_dropped():
    """Left-pad and inactive-row writes must not touch any page."""
    cfg = get_config("qwen2-0.5b").reduced()
    rt = Runtime(cache_dtype="bfloat16", aligned_decode=False)
    sv = ServingConfig(layout="paged", max_batch=1, page_size=4,
                       num_pages=4, max_ctx=16)
    paged = init_paged_attn_cache(cfg, rt, 1, sv)
    paged = dict(paged, tbl=jnp.arange(4, dtype=jnp.int32)[None])
    k = jnp.ones((1, 3, cfg.n_kv_heads, cfg.hd), jnp.bfloat16)
    out = paged_write(paged, k, k, jnp.asarray([[-2, -1, 5]], jnp.int32))
    pool = np.asarray(out["k"], np.float32)
    assert pool.reshape(16, -1)[5].all()            # the valid slot landed
    assert (pool.reshape(16, -1)[[0, 1, 2, 3, 4]] == 0).all()


# ------------------------------------------------------------- engine e2e --
@pytest.fixture(scope="module")
def reduced_cfg():
    return get_config("qwen2-0.5b").reduced()


def _engine(cfg, layout, num_pages=32, seed=0):
    rt = Runtime(quant_backend="float", cache_dtype="bfloat16", remat="none",
                 loss_chunk=0)
    sv = ServingConfig(layout=layout, max_batch=2, page_size=8,
                       num_pages=num_pages, max_ctx=32)
    return InferenceEngine(cfg, rt, sv, seed=seed)


def test_engine_paged_vs_contiguous_bit_identical(reduced_cfg):
    trace = poisson_trace(4, 1.0, [8], [4], reduced_cfg.vocab, seed=5)
    _, fin_p = run_trace(_engine(reduced_cfg, "paged"), trace)
    _, fin_c = run_trace(_engine(reduced_cfg, "contiguous"), trace)
    assert [r.tokens for r in fin_p] == [r.tokens for r in fin_c]
    assert all(len(r.tokens) == 4 for r in fin_p)


def test_engine_preemption_resume_completes(reduced_cfg):
    # 6 pages of 4 tokens for 2 slots of up to 8+8 tokens: decode pressure
    rt = Runtime(quant_backend="float", cache_dtype="bfloat16", remat="none")
    sv = ServingConfig(layout="paged", max_batch=2, page_size=4,
                       num_pages=6, max_ctx=16)
    engine = InferenceEngine(reduced_cfg, rt, sv, seed=0)
    trace = poisson_trace(4, 2.0, [8], [8], reduced_cfg.vocab, seed=9)
    stats, fin = run_trace(engine, trace)
    assert stats["requests_finished"] == 4
    assert all(len(r.tokens) == 8 for r in fin)
    assert stats["requests_preempted"] >= 1
    # preemption is recompute-style and greedy decode is deterministic:
    # an unconstrained pool must produce the same tokens
    _, fin_big = run_trace(_engine(reduced_cfg, "paged", num_pages=32), trace)
    assert [r.tokens for r in fin] == [r.tokens for r in fin_big]
