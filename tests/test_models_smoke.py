"""Per-architecture smoke tests (assignment requirement): a REDUCED config of
the same family runs one forward/train step on CPU with finite outputs and the
right shapes; plus serve-path (prefill+decode) consistency against the full
forward, and QAT-backend equivalence checks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, Runtime, get_config
from repro.models import decode_step, init_caches, init_model, lm_loss, prefill
from repro.models.transformer import forward, _logits

RT = Runtime(scan_layers=True, attn_impl="chunked", attn_chunk_q=8, loss_chunk=0)


@pytest.mark.parametrize("arch", sorted(REGISTRY))
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg)
    toks = jax.random.randint(key, (2, 17), 0, cfg.vocab)

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: lm_loss(p, toks, cfg, RT), has_aux=True
    )(params)
    assert np.isfinite(float(loss))
    for leaf in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


@pytest.mark.parametrize("arch", sorted(REGISTRY))
def test_reduced_forward_shapes(arch):
    cfg = get_config(arch).reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    logits, _, _ = forward(params, toks, cfg, RT)
    assert logits.shape == (2, 16, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", sorted(REGISTRY))
def test_prefill_decode_matches_forward(arch):
    """Serve path == train path (f32 cache; exactness catches cache bugs).

    MoE archs use a dropless capacity factor here: capacity-based token drop
    depends on the number of tokens in flight, so the train-shaped forward
    and the 1-token decode legitimately differ when drops occur -- that is a
    property of capacity routing (GShard), not a cache bug.
    """
    cfg = get_config(arch).reduced(capacity_factor=64.0)
    rt = Runtime(scan_layers=True, attn_impl="chunked", attn_chunk_q=8,
                 loss_chunk=0, compute_dtype="float32", quant_backend="float",
                 cache_dtype="float32")
    params = init_model(jax.random.PRNGKey(1), cfg)
    B, S, P = 2, 24, 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)

    hidden, _, _ = forward(params, toks, cfg, rt, return_hidden=True)
    full_logits = np.asarray(_logits(params, hidden, cfg, rt), np.float32)

    caches = init_caches(cfg, rt, batch=B, seq=S)
    lg, caches = prefill(params, toks[:, :P], cfg, rt, caches)
    errs = [np.max(np.abs(np.asarray(lg, np.float32) - full_logits[:, P - 1]))]
    for t in range(P, S):
        pos = jnp.full((B, 1), t, jnp.int32)
        lg, caches = decode_step(params, toks[:, t:t + 1], cfg, rt, caches, pos)
        errs.append(np.max(np.abs(np.asarray(lg, np.float32) - full_logits[:, t])))
    assert max(errs) < 5e-5, (arch, max(errs))


def test_scan_matches_unrolled():
    """scan-over-layers and the unrolled cost-probe agree to float32
    tolerance (same math, but XLA may fuse/reassociate differently)."""
    cfg = get_config("qwen3-4b").reduced(n_layers=3)
    params = init_model(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    rt_scan = Runtime(scan_layers=True, loss_chunk=0, compute_dtype="float32")
    rt_unroll = Runtime(scan_layers=False, loss_chunk=0, compute_dtype="float32")
    l1, _ = lm_loss(params, toks, cfg, rt_scan)
    l2, _ = lm_loss(params, toks, cfg, rt_unroll)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_chunked_loss_matches_unchunked():
    cfg = get_config("qwen2-0.5b").reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0, cfg.vocab)
    rt_a = Runtime(loss_chunk=0, compute_dtype="float32")
    rt_b = Runtime(loss_chunk=8, compute_dtype="float32")
    la, _ = lm_loss(params, toks, cfg, rt_a)
    lb, _ = lm_loss(params, toks, cfg, rt_b)
    np.testing.assert_allclose(float(la), float(lb), rtol=1e-6)


def test_int8_kv_cache_close_to_f32():
    """§Perf lever: int8 KV cache stays within quantization error."""
    cfg = get_config("qwen3-4b").reduced()
    params = init_model(jax.random.PRNGKey(1), cfg)
    B, S, P = 2, 24, 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    outs = {}
    for cd in ("float32", "int8"):
        rt = Runtime(attn_chunk_q=8, loss_chunk=0, compute_dtype="float32",
                     quant_backend="float", cache_dtype=cd)
        caches = init_caches(cfg, rt, batch=B, seq=S)
        lg, caches = prefill(params, toks[:, :P], cfg, rt, caches)
        pos = jnp.full((B, 1), P, jnp.int32)
        lg, _ = decode_step(params, toks[:, P:P + 1], cfg, rt, caches, pos)
        outs[cd] = np.asarray(jax.nn.softmax(lg.astype(jnp.float32)), np.float32)
    err = np.max(np.abs(outs["int8"] - outs["float32"]))
    assert err < 0.05, err


def test_local_window_ring_buffer_wraps():
    """Decode far past the window: ring cache must stay correct."""
    cfg = get_config("recurrentgemma-9b").reduced()
    assert cfg.local_window == 16
    rt = Runtime(attn_chunk_q=8, loss_chunk=0, compute_dtype="float32",
                 quant_backend="float", cache_dtype="float32")
    params = init_model(jax.random.PRNGKey(1), cfg)
    B, S, P = 1, 40, 8          # decode to 40 >> window 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    hidden, _, _ = forward(params, toks, cfg, rt, return_hidden=True)
    full_logits = np.asarray(_logits(params, hidden, cfg, rt), np.float32)
    caches = init_caches(cfg, rt, batch=B, seq=S)
    lg, caches = prefill(params, toks[:, :P], cfg, rt, caches)
    errs = []
    for t in range(P, S):
        pos = jnp.full((B, 1), t, jnp.int32)
        lg, caches = decode_step(params, toks[:, t:t + 1], cfg, rt, caches, pos)
        errs.append(np.max(np.abs(np.asarray(lg, np.float32) - full_logits[:, t])))
    assert max(errs) < 5e-5, max(errs)


def test_moe_routes_to_multiple_experts():
    cfg = get_config("arctic-480b").reduced()
    from repro.models.moe import _moe_shard, init_moe
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
    y, aux = _moe_shard(x, p["router"]["w"], p["experts"],
                        e_start=0, n_local=cfg.n_experts, cfg=cfg, rt=RT)
    assert y.shape == x.shape and np.isfinite(np.asarray(y)).all()
    assert float(aux[0]) > 0.5          # aux ~1 for near-uniform routing


def test_int4_kv_cache_close_to_f32():
    """Beyond-paper lever: the paper's 4-bit format on the KV cache."""
    cfg = get_config("qwen3-4b").reduced()
    params = init_model(jax.random.PRNGKey(1), cfg)
    B, S, P = 2, 24, 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    outs = {}
    for cd in ("float32", "int4"):
        rt = Runtime(attn_chunk_q=8, loss_chunk=0, compute_dtype="float32",
                     quant_backend="float", cache_dtype=cd)
        caches = init_caches(cfg, rt, batch=B, seq=S)
        lg, caches = prefill(params, toks[:, :P], cfg, rt, caches)
        pos = jnp.full((B, 1), P, jnp.int32)
        lg, _ = decode_step(params, toks[:, P:P + 1], cfg, rt, caches, pos)
        outs[cd] = np.asarray(jax.nn.softmax(lg.astype(jnp.float32)), np.float32)
    assert np.max(np.abs(outs["int4"] - outs["float32"])) < 0.05


def test_unaligned_scatter_cache_matches_aligned_dus():
    """The ragged (scatter) and batch-aligned (DUS) write paths agree."""
    cfg = get_config("qwen2-0.5b").reduced()
    params = init_model(jax.random.PRNGKey(1), cfg)
    B, S, P = 2, 20, 12
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    outs = []
    for aligned in (True, False):
        rt = Runtime(attn_chunk_q=8, loss_chunk=0, compute_dtype="float32",
                     quant_backend="float", cache_dtype="float32",
                     aligned_decode=aligned)
        caches = init_caches(cfg, rt, batch=B, seq=S)
        lg, caches = prefill(params, toks[:, :P], cfg, rt, caches)
        pos = jnp.full((B, 1), P, jnp.int32)
        lg, _ = decode_step(params, toks[:, P:P + 1], cfg, rt, caches, pos)
        outs.append(np.asarray(lg, np.float32))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-5)
