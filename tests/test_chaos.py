"""Request-lifecycle hardening: cancellation, deadlines, load shedding,
stop/resume, the step watchdog, and the seeded chaos harness.

The scheduler-level tests drive Scheduler + PagedKVCacheManager directly
(no model, fast); the engine-level tests run the reduced config end to end
so cancel/timeout/shed retirements, snapshot/restore token identity, and
the fault-injection paths are exercised against real jitted steps.
"""

import dataclasses

import numpy as np
import pytest

from repro.configs import Runtime, ServingConfig, get_config
from repro.distributed.fault_tolerance import (
    StepDeadlineExceeded,
    run_with_retries,
)
from repro.serving.chaos import ChaosConfig, InjectedFault, _StepClock, run_chaos
from repro.serving.engine import (
    EngineStuckError,
    InferenceEngine,
    build_params,
)
from repro.serving.kv_pages import PagedKVCacheManager
from repro.serving.scheduler import (
    CANCELLED,
    OK,
    Request,
    Scheduler,
    SHED,
    ShedError,
    TIMEOUT,
)

RT = Runtime(quant_backend="float", cache_dtype="bfloat16", remat="none",
             loss_chunk=0)


# ---------------------------------------------------- scheduler unit tests --
def _sched(max_queue=0, num_pages=16):
    sv = ServingConfig(layout="paged", max_batch=2, page_size=4,
                       num_pages=num_pages, max_ctx=16, max_queue=max_queue)
    kv = PagedKVCacheManager(sv)
    return kv, Scheduler(kv, max_batch=2, max_queue=max_queue)


def _rq(rid, L=6, **kw):
    return Request(rid=rid, prompt=np.arange(L, dtype=np.int32) + rid,
                   max_new=4, **kw)


def test_cancel_queued_request_leaves_no_trace():
    kv, sched = _sched()
    for rid in range(3):
        sched.submit(_rq(rid))
    sched.admit(0.0)                       # max_batch=2: rid 2 still queued
    retired = sched.cancel(2, now=1.0)
    assert retired is not None and retired.outcome == CANCELLED
    assert retired.t_finish == 1.0
    assert 2 not in kv.pages and not sched.waiting
    kv.check_invariants()
    sched.check_invariants()


def test_cancel_running_releases_pages_and_slot():
    kv, sched = _sched()
    sched.submit(_rq(0))
    sched.submit(_rq(1))
    sched.admit(0.0)
    held = len(kv.pages[0])
    assert held > 0
    in_use_before = kv.in_use
    assert sched.cancel(0, now=1.0).outcome == CANCELLED
    assert 0 not in kv.pages and kv.in_use < in_use_before
    assert 0 not in sched.running and len(sched._free_slots) == 1
    # unknown / already-retired rids are a no-op, not an error
    assert sched.cancel(0, now=2.0) is None
    assert sched.cancel(99, now=2.0) is None
    kv.check_invariants()
    sched.check_invariants()


def test_expire_sweeps_waiting_and_running():
    kv, sched = _sched()
    for rid in range(3):
        sched.submit(_rq(rid))
    sched.admit(0.0)                       # FIFO: 0,1 running; 2 queued
    sched.running[0].deadline = 5.0        # set post-admission so EDF
    sched.waiting[0].deadline = 3.0        # doesn't reorder the batch
    # rid 1 carries no deadline: never expires
    assert sched.expire(2.9) == []
    expired = sched.expire(5.0)            # sweeps both overdue requests
    assert sorted(r.rid for r in expired) == [0, 2]
    assert all(r.outcome == TIMEOUT for r in expired)
    assert 0 not in kv.pages and 2 not in kv.pages
    assert list(sched.running) == [1]
    kv.check_invariants()
    sched.check_invariants()


def test_edf_admission_prefers_tightest_deadline():
    kv, sched = _sched()
    sched.submit(_rq(0))                   # FIFO head, but deadline-less
    sched.submit(_rq(1, deadline=50.0))
    sched.submit(_rq(2, deadline=10.0))
    admitted = [r.rid for r in sched.admit(0.0)]
    assert admitted == [2, 1]              # EDF ahead of the FIFO tail
    assert [r.rid for r in sched.waiting] == [0]


def test_bounded_queue_sheds_with_typed_error():
    kv, sched = _sched(max_queue=1)
    sched.submit(_rq(0))
    with pytest.raises(ShedError):
        sched.submit(_rq(1))
    assert 1 not in kv.pages               # shed before holding anything
    sched.admit(0.0)                       # queue drains -> submits succeed
    sched.submit(_rq(2))
    sched.check_invariants()


# ------------------------------------------------------- engine e2e tests --
@pytest.fixture(scope="module")
def cfg():
    return get_config("qwen2-0.5b").reduced()


@pytest.fixture(scope="module")
def params(cfg):
    return build_params(cfg, RT)


def _engine(cfg, params, clock=None, **sv_kw):
    sv_args = dict(layout="paged", max_batch=2, page_size=8, num_pages=32,
                   max_ctx=32)
    sv_args.update(sv_kw)
    kw = {"clock": clock} if clock is not None else {}
    return InferenceEngine(cfg, RT, ServingConfig(**sv_args),
                           params=params, **kw)


def _prompt(cfg, L=8, shift=0):
    return (np.arange(L, dtype=np.int32) * 3 + shift) % cfg.vocab


def test_engine_cancel_queued_and_decoding(cfg, params):
    eng = _engine(cfg, params)
    eng.warmup([8])
    r0 = eng.submit(_prompt(cfg), 6)
    r1 = eng.submit(_prompt(cfg, shift=7), 6)
    r2 = eng.submit(_prompt(cfg, shift=21), 6)   # max_batch=2: queued
    eng.step()
    eng.step()
    assert eng.cancel(r0)                  # mid-decode
    assert eng.cancel(r2)                  # still queued
    assert not eng.cancel(r0)              # already retired: False, no raise
    assert r0 not in eng.kv.pages and r2 not in eng.kv.pages
    eng.run_until_idle()
    fin = {r.rid: r for r in eng.collect()}
    assert fin[r0].outcome == CANCELLED and fin[r2].outcome == CANCELLED
    assert fin[r1].outcome == OK and len(fin[r1].tokens) == 6
    assert eng.kv.in_use == 0
    counters = eng.metrics.snapshot()["counters"]
    assert counters["serving_cancelled_total"] == 2
    assert counters['requests_retired_total{outcome="cancelled"}'] == 2
    assert counters['requests_retired_total{outcome="ok"}'] == 1
    assert eng.stats()["outcomes"] == {"ok": 1, "cancelled": 2}


def test_engine_deadline_retires_with_timeout(cfg, params):
    clock = _StepClock()
    eng = _engine(cfg, params, clock=clock)
    eng.warmup([8])
    rid = eng.submit(_prompt(cfg), 20, deadline_s=3.0)
    keep = eng.submit(_prompt(cfg, shift=5), 4)   # no deadline: unaffected
    for t in range(8):
        clock.t = float(t)
        eng.step()
    fin = {r.rid: r for r in eng.collect()}
    assert fin[rid].outcome == TIMEOUT
    assert 0 < len(fin[rid].tokens) < 20   # made progress, then expired
    assert fin[keep].outcome == OK and len(fin[keep].tokens) == 4
    assert eng.kv.in_use == 0
    assert eng.metrics.snapshot()["counters"]["serving_timeout_total"] == 1


def test_engine_shed_is_collectable(cfg, params):
    eng = _engine(cfg, params, max_queue=1, max_batch=1)
    eng.warmup([8])
    r0 = eng.submit(_prompt(cfg), 4)
    with pytest.raises(ShedError):
        eng.submit(_prompt(cfg, shift=11), 4)
    eng.run_until_idle()
    fin = {r.rid: r for r in eng.collect()}
    assert len(fin) == 2                   # the shed request still retires
    assert fin[r0].outcome == OK
    assert sorted(r.outcome for r in fin.values()) == [OK, SHED]
    assert eng.metrics.snapshot()["counters"]["serving_shed_total"] == 1


def test_snapshot_restore_token_identity(cfg, params):
    prompts = [_prompt(cfg), _prompt(cfg, shift=13)]

    def drain(eng, clock, step0, out):
        step = step0
        while not eng.scheduler.idle:
            assert step < 200
            clock.t = float(step)
            eng.step()
            for r in eng.collect():
                out[r.rid] = list(r.tokens)
            step += 1
        return out

    c_ref = _StepClock()
    ref = _engine(cfg, params, clock=c_ref)
    ref.warmup([8])
    for p in prompts:
        ref.submit(p, 8)
    expect = drain(ref, c_ref, 0, {})

    clock = _StepClock()
    eng = _engine(cfg, params, clock=clock)
    eng.warmup([8])
    for p in prompts:
        eng.submit(p, 8)
    for step in range(3):                  # stop mid-decode
        clock.t = float(step)
        eng.step()
    snap = eng.snapshot()
    eng2 = InferenceEngine.restore(snap, params=params, clock=clock)
    eng2.kv.check_invariants()
    eng2.scheduler.check_invariants()
    got = drain(eng2, clock, 3, {})
    assert got == expect                   # bit-identical continuation
    assert eng2.kv.in_use == 0


def test_injected_step_fault_is_survivable(cfg, params):
    eng = _engine(cfg, params)
    eng.warmup([8])
    rid = eng.submit(_prompt(cfg), 4)
    eng.inject_step_fault(InjectedFault("boom"))
    run_with_retries(eng.step, max_retries=2)   # first attempt raises
    eng.run_until_idle()
    fin = {r.rid: r for r in eng.collect()}
    assert fin[rid].outcome == OK and len(fin[rid].tokens) == 4
    # undecorated, the planted fault escapes (typed, so tests can tell)
    eng.inject_step_fault(InjectedFault("boom2"))
    with pytest.raises(InjectedFault):
        eng.step()


def test_watchdog_counts_and_strict_raises(cfg, params):
    eng = _engine(cfg, params, step_deadline_s=1e-6)
    eng.warmup([8])
    eng.submit(_prompt(cfg), 3)
    eng.run_until_idle()                   # lenient: counts, never raises
    c = eng.metrics.snapshot()["counters"]
    assert c["serving_step_deadline_exceeded_total"] >= 1
    assert {r.outcome for r in eng.collect()} == {OK}

    strict = _engine(cfg, params, step_deadline_s=1e-6,
                     step_deadline_strict=True)
    strict.warmup([8])
    strict.submit(_prompt(cfg), 3)
    with pytest.raises(StepDeadlineExceeded):
        strict.run_until_idle()


def test_run_until_idle_raises_typed_stuck_error(cfg, params):
    clock = _StepClock()                   # frozen at 0: arrival never comes
    eng = _engine(cfg, params, clock=clock)
    eng.warmup([8])
    rid = eng.submit(_prompt(cfg), 4, arrival=100.0)
    with pytest.raises(EngineStuckError) as ei:
        eng.run_until_idle(max_steps=3)
    assert ei.value.queued == [rid] and ei.value.running == []
    assert ei.value.max_steps == 3
    assert eng.metrics.snapshot()["counters"][
        "serving_engine_stuck_total"] == 1


def test_chaos_harness_smoke(cfg, params):
    rt = dataclasses.replace(RT, attn_impl="chunked", attn_chunk_q=32)
    sv = ServingConfig(layout="paged", max_batch=2, page_size=8,
                       num_pages=32, max_ctx=32, max_queue=4)
    chaos = ChaosConfig(seed=0, n_requests=6, prompt_lens=(6, 10),
                        gen_lens=(4, 6), stop_resume_at=(3,))
    rep = run_chaos(cfg, rt, sv, chaos, params=params)
    assert rep["survivors_identical"]
    assert rep["leaked_pages"] == 0
    assert rep["recompiles_steady_state"] == 0
    assert sum(rep["outcomes"].values()) == chaos.n_requests
    assert rep["events"]["stop_resumes"] == 1
