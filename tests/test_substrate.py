"""Substrate tests: data pipeline determinism/sharding, optimizer, schedule,
checkpoint atomicity + elastic restore, watchdog/retry fault tolerance."""

import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, save_checkpoint
from repro.data import SyntheticLMDataset, make_batch_iterator
from repro.distributed.fault_tolerance import StepTimer, Watchdog, run_with_retries
from repro.optim import adamw_init, adamw_update, warmup_cosine


# ------------------------------------------------------------------- data --
def test_data_deterministic_and_resumable():
    ds = SyntheticLMDataset(vocab=256, seq_len=32, global_batch=8, seed=3)
    b1 = ds.batch(7)
    b2 = ds.batch(7)
    np.testing.assert_array_equal(b1, b2)
    assert b1.shape == (8, 33) and b1.dtype == np.int32
    assert (b1 >= 0).all() and (b1 < 256).all()


def test_data_sharding_partitions_global_batch():
    full = SyntheticLMDataset(vocab=128, seq_len=8, global_batch=8, seed=0)
    shards = [
        SyntheticLMDataset(vocab=128, seq_len=8, global_batch=8, seed=0,
                           shard_index=i, shard_count=4)
        for i in range(4)
    ]
    got = np.concatenate([s.batch(5) for s in shards], axis=0)
    np.testing.assert_array_equal(got, full.batch(5))


def test_data_iterator_prefetch_and_resume():
    ds = SyntheticLMDataset(vocab=64, seq_len=8, global_batch=2, seed=1)
    it = make_batch_iterator(ds, start_step=10)
    first = next(it)
    np.testing.assert_array_equal(first, ds.batch(10))
    it.close()


def test_data_is_learnable_not_uniform():
    ds = SyntheticLMDataset(vocab=512, seq_len=256, global_batch=4, seed=0)
    b = ds.batch(0)
    # Zipf + copy structure => strongly non-uniform unigram distribution
    _, counts = np.unique(b, return_counts=True)
    assert counts.max() > 5 * counts.mean()


# ------------------------------------------------------------------ optim --
def test_adamw_reduces_quadratic_loss():
    params = {"w": jnp.ones((4,)) * 5.0}
    opt = adamw_init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt, info = adamw_update(
            params, g, opt, lr=0.05, weight_decay=0.0)
    assert float(loss(params)) < 1e-2
    assert np.isfinite(float(info["grad_norm"]))


def test_grad_clipping_bounds_update():
    params = {"w": jnp.zeros((4,))}
    opt = adamw_init(params)
    g = {"w": jnp.full((4,), 1e6)}
    p2, opt, info = adamw_update(params, g, opt, lr=1.0, max_grad_norm=1.0,
                                 weight_decay=0.0)
    assert float(info["grad_norm"]) > 1e5          # reported pre-clip
    assert np.all(np.abs(np.asarray(p2["w"])) < 1.5e0)


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(s, peak_lr=1e-3, warmup_steps=10,
                               total_steps=100)) for s in range(100)]
    assert lrs[0] < lrs[9] and abs(lrs[10] - 1e-3) < 1e-9
    assert lrs[99] < lrs[50] < lrs[10]


# ------------------------------------------------------------- checkpoint --
def test_checkpoint_roundtrip_and_gc(tmp_path):
    d = str(tmp_path)
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)}}
    mgr = CheckpointManager(d, save_every=1, keep=2)
    for step in (1, 2, 3):
        mgr.maybe_save(step, jax.tree.map(lambda x: x * step, tree))
    assert mgr.latest() == 3
    restored, step = mgr.restore(tree)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.arange(6).reshape(2, 3) * 3)
    assert restored["nested"]["b"].dtype == jnp.bfloat16
    # keep=2 -> step 1 garbage-collected
    assert not os.path.exists(os.path.join(d, "step_00000001"))


def test_checkpoint_atomicity_ignores_partial(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 5, {"x": jnp.zeros(3)})
    # simulate a crashed save: tmp dir without manifest
    os.makedirs(os.path.join(d, "step_00000009.tmp_dead"), exist_ok=True)
    os.makedirs(os.path.join(d, "step_00000010"), exist_ok=True)  # no manifest
    assert latest_step(d) == 5
    assert not os.path.exists(os.path.join(d, "step_00000009.tmp_dead"))


def test_checkpoint_elastic_restore_new_sharding(tmp_path):
    """Restore under a different sharding (elastic re-mesh on load)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    d = str(tmp_path)
    tree = {"w": jnp.arange(8, dtype=jnp.float32)}
    save_checkpoint(d, 1, tree)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))
    shardings = {"w": NamedSharding(mesh, P(None))}
    restored, _ = CheckpointManager(d).restore(tree, shardings=shardings)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(8))
    assert restored["w"].sharding == shardings["w"]


# --------------------------------------------------------- fault tolerance --
def test_watchdog_fires_on_hang():
    fired = threading.Event()
    wd = Watchdog(deadline_s=0.05, on_timeout=fired.set)
    with wd:
        # wait for the callback rather than sleeping a fixed window: on a
        # loaded machine the timer thread can be starved past any margin
        assert fired.wait(timeout=10.0)
    assert wd.fired.is_set()


def test_watchdog_quiet_on_fast_step():
    wd = Watchdog(deadline_s=1.0)
    with wd:
        time.sleep(0.01)
    assert not wd.fired.is_set()


def test_run_with_retries_recovers():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return "ok"

    assert run_with_retries(flaky, max_retries=3) == "ok"
    assert calls["n"] == 3


def test_run_with_retries_raises_after_budget():
    def dead():
        raise RuntimeError("permanent")

    with pytest.raises(RuntimeError):
        run_with_retries(dead, max_retries=2)


def test_step_timer_straggler_detection():
    t = StepTimer(alpha=1.0)
    t.start(); time.sleep(0.05); t.stop()
    assert t.is_straggler(cluster_median_s=0.01, factor=1.5)
    assert not t.is_straggler(cluster_median_s=0.05, factor=1.5)
