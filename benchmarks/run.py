"""Benchmark harness — one function per paper table/figure + kernel and
system benchmarks.  Prints ``name,us_per_call,derived`` CSV rows.

  table2   -> paper Table II  (resources: LUTs / CARRY4 per design)
  table3   -> paper Table III (critical-path delay, logic/net split)
  fig5     -> paper Fig. 5    (area x delay frontier points)
  pipeline -> paper §VI       (pipelined Fmax)
  kernels  -> TPU-adaptation kernels: us/call + GOP/s vs the jnp oracle
  paged_attn -> fused paged-decode attention vs the gather baseline
              (tokens/s vs context length at several page sizes) + flash
              vs chunked prefill
  gemm     -> quantized-GEMM backends (the "multiplier array" system view)
  serving  -> continuous-batching engine: paged vs contiguous KV tokens/s
  sensitivity -> per-site quant sensitivity sweep (one site group floated
              at a time; logits-MSE vs uniform-W4 — §Mixed precision)

CLI::

  python -m benchmarks.run [sections...] [--out BENCH_kernels.json]
                           [--baseline benchmarks/BENCH_kernels.json]
                           [--gate-tol 1.25] [--autotune]

``--out`` writes every emitted row to JSON; ``--baseline`` gates the run
against a committed baseline (exit 1 on regression).  Because absolute
microseconds differ across hosts, the gate is *host-normalized*: the
median of per-row current/baseline ratios estimates the host-speed factor
(uniform machine-speed shifts cancel; a single regressed row stands out),
and a row fails when its ratio exceeds ``--gate-tol`` times that median.
Rows that measure the Pallas *interpreter* (suffix ``_interp``) are
diagnostics, not an execution path, and are excluded; ``--repeat 3`` keeps
per-row minima across process-level repeats to smooth CI-runner noise.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

#: rows collected by emit() for --out / --baseline
ROWS = {}

#: rows faster than this are dispatch-overhead noise, not gate material
#: (sub-ms XLA-CPU rows swing +-25% with thread scheduling alone)
GATE_FLOOR_US = 500.0


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}")
    prev = ROWS.get(name)
    # --repeat keeps the best (us, derived) *pair* — never the min us of
    # one repeat with the derived gflops of a slower one
    if prev is None or not prev["us"] or not us or us < prev["us"]:
        ROWS[name] = {"us": float(us), "derived": derived}


def _time(fn, *args, reps=7, warmup=2) -> float:
    """Min wall-time per call in microseconds (min-of-N is the noise-robust
    estimator the perf gate depends on: load spikes only ever add time)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.min(ts))


def bench_table2():
    from repro.core import (
        PUBLISHED_ROWS, build_acc_mult4, build_lm_mult4,
        build_proposed_mult4, resources,
    )

    ours = {
        "proposed": resources(build_proposed_mult4()),
        "lm": resources(build_lm_mult4()),
        "acc_ullah": resources(build_acc_mult4()),
    }
    for name, row in PUBLISHED_ROWS.items():
        o = ours.get(name)
        derived = (f"luts={o['luts']};carry4={o['carry4']};"
                   f"pub_luts={row['luts']};pub_carry4={row['carry4']}"
                   if o else f"pub_luts={row['luts']};pub_carry4={row['carry4']}")
        emit(f"table2.{name}", 0.0, derived)


def bench_table3():
    from repro.core import (
        PUBLISHED_ROWS, analyze, build_acc_mult4, build_lm_mult4,
        build_proposed_mult4,
    )

    ours = {
        "proposed": analyze(build_proposed_mult4()),
        "lm": analyze(build_lm_mult4()),
        "acc_ullah": analyze(build_acc_mult4()),
    }
    for name, row in PUBLISHED_ROWS.items():
        if row.get("cpd") is None and name not in ours:
            continue
        o = ours.get(name)
        parts = []
        if o:
            parts.append(f"cpd={o['cpd']};logic={o['logic']};net={o['net']}")
        if row.get("cpd") is not None:
            parts.append(f"pub_cpd={row['cpd']}")
        emit(f"table3.{name}", 0.0, ";".join(parts))


def bench_fig5():
    from repro.core import PUBLISHED_ROWS, analyze, build_proposed_mult4

    t = analyze(build_proposed_mult4())
    for name, row in PUBLISHED_ROWS.items():
        if row.get("cpd") is None:
            continue
        emit(f"fig5.{name}", 0.0, f"luts={row['luts']};cpd={row['cpd']}")
    emit("fig5.proposed_ours", 0.0, f"luts=11;cpd={t['cpd']}")


def bench_pipeline():
    from repro.core.pipeline_mult import pipelined_report

    rep = pipelined_report()
    emit("pipeline.proposed", 0.0,
         f"fmax_mhz={rep['fmax_mhz']};unpipelined={rep['unpipelined_fmax_mhz']};"
         f"stage1={rep['stage1_ns']};stage2={rep['stage2_ns']}")


# GEMM shapes the kernel bench times and (on TPU / --autotune) tunes.
GEMM_SHAPES = {
    "prefill": (256, 512, 512),
    "decode": (8, 512, 512),
}


def _maybe_tune(do_tune: bool, on_tpu: bool):
    """Run the block-size search for each bench GEMM shape when requested
    (TPU hosts, REPRO_AUTOTUNE=1, or --autotune).

    Each op is tuned under the exact cache key its ops-wrapper looks up at
    serving time — (op, shape, *activation* dtype, group size, backend) —
    otherwise the tuned entries would never be hit: int4_matmul keys on the
    int8 a_q, the fused variant on its float x, w4a16 on bf16 x + G."""
    if not do_tune:
        return
    from repro.core.quant import group_quantize, pack_int4
    from repro.kernels import autotune, ops

    rng = np.random.default_rng(7)
    interp = None if on_tpu else True
    for shape_name, (M, K, N) in GEMM_SHAPES.items():
        aq = jnp.asarray(rng.integers(-8, 8, size=(M, K), dtype=np.int8))
        a_s = jnp.ones((M, 1), jnp.float32)
        x = jnp.asarray(rng.standard_normal((M, K)).astype(np.float32))
        xb = x.astype(jnp.bfloat16)
        wp = pack_int4(
            jnp.asarray(rng.integers(-8, 8, size=(K, N), dtype=np.int8)), -1)
        ws = jnp.ones((1, N), jnp.float32)
        qg, sg = group_quantize(
            jnp.asarray(rng.standard_normal((K, N)).astype(np.float32)), 128)
        wpg = pack_int4(qg, -1)

        specs = [
            ("int4_matmul", "int8", 0, lambda b:
                lambda: ops.int4_matmul(aq, a_s, wp, ws,
                                        interpret=interp, **b)),
            ("int4_matmul_fused", "float32", 0, lambda b:
                lambda: ops.int4_matmul_fused(x, wp, ws,
                                              interpret=interp, **b)),
            ("w4a16_matmul", "bfloat16", 128, lambda b:
                lambda: ops.w4a16_matmul(xb, wpg, sg, 128,
                                         interpret=interp, **b)),
            ("gemm.lut4", "int8", 0, lambda b:
                lambda: ops.lut4_matmul(aq, a_s, wp, ws,
                                        interpret=interp, **b)),
        ]
        for op, dtype, g, make_call in specs:
            default = (autotune.lut4_default_blocks(M, K, N)
                       if op == autotune.LUT4_OP
                       else autotune.default_blocks(M, K, N, group_size=g))
            blocks, us = autotune.tune(op, make_call, M, K, N, dtype,
                                       group_size=g)
            emit(f"kernels.autotune.{op}.{shape_name}", us,
                 f"bm={blocks['bm']};bn={blocks['bn']};bk={blocks['bk']};"
                 f"default_bm={default['bm']};default_bk={default['bk']}")


def bench_kernels(do_tune: bool = False):
    from repro.core.quant import group_quantize, pack_int4
    from repro.kernels import ops, packing, ref

    rng = np.random.default_rng(0)
    # elementwise LUT multiplier array, 1M elements.  The Pallas LUT kernel
    # only *lowers* on TPU; elsewhere it runs through the interpreter, so
    # those rows carry the _interp suffix and are excluded from the gate.
    on_tpu = jax.default_backend() == "tpu"
    suffix = "" if on_tpu else "_interp"
    n = 1 << 20
    a = jnp.asarray(rng.integers(-8, 8, size=n, dtype=np.int8))
    b = jnp.asarray(rng.integers(-8, 8, size=n, dtype=np.int8))
    for strat in ("onehot", "take"):
        fn = jax.jit(lambda x, y, s=strat: ops.mul4(
            x, y, strategy=s, interpret=not on_tpu))
        us = _time(fn, a, b)
        emit(f"kernels.lut_mul4_{strat}{suffix}", us, f"gops={n/us*1e-3:.2f}")
    fn = jax.jit(ref.mul4_ref)
    us = _time(fn, a, b)
    emit("kernels.mul4_xla_ref", us, f"gops={n/us*1e-3:.2f}")

    # netlist bit-sim multiplier array (the paper's circuit, vectorized)
    from repro.core import build_proposed_mult4
    nl = build_proposed_mult4()
    au = jnp.asarray(rng.integers(0, 16, size=n, dtype=np.uint8))
    bu = jnp.asarray(rng.integers(0, 16, size=n, dtype=np.uint8))
    fn = jax.jit(lambda x, y: nl(x, y))
    us = _time(fn, au, bu)
    emit("kernels.netlist_sim", us, f"gops={n/us*1e-3:.2f}")

    # quantized matmul kernels vs oracles, prefill + decode GEMM shapes.
    # Dispatch rows time what models actually execute on this host (Mosaic
    # kernels on TPU, XLA twins elsewhere); _interp rows cover the kernel
    # bodies when not on TPU.
    for shape_name, (M, K, N) in GEMM_SHAPES.items():
        flops = 2 * M * K * N
        aq = jnp.asarray(rng.integers(-8, 8, size=(M, K), dtype=np.int8))
        a_s = jnp.asarray(rng.random((M, 1), dtype=np.float32) + 0.05)
        x = jnp.asarray(rng.standard_normal((M, K)).astype(np.float32))
        xb = x.astype(jnp.bfloat16)
        wq = jnp.asarray(rng.integers(-8, 8, size=(K, N), dtype=np.int8))
        w_s = jnp.asarray(rng.random((1, N), dtype=np.float32) + 0.05)
        wp = pack_int4(wq, -1)
        wf = jnp.asarray(rng.standard_normal((K, N)).astype(np.float32)) * 0.05
        qg, sg = group_quantize(wf, 128)
        wpg = pack_int4(qg, -1)

        # arrays are passed as jit *arguments* so XLA can't constant-fold
        # the contraction away, and weights are prepacked to the planar
        # K-major layout *outside* the timed call — that is what the
        # serving path executes (build_params/prepack_tree twins); passing
        # the interleaved weight through jit would time a per-call relayout
        # the real models never pay
        w_km = packing.prepack_kmajor(wp)
        w_kmg = packing.prepack_kmajor(wpg, row_mult=2 * 128)
        rows = {
            f"int4_matmul.{shape_name}": (
                jax.jit(lambda a1, a2, a3, a4:
                        ops.int4_matmul_kmajor(a1, a2, a3, a4)),
                (aq, a_s, w_km, w_s)),
            f"int4_matmul_fused.{shape_name}": (
                jax.jit(lambda a1, a2, a3:
                        ops.int4_matmul_fused_kmajor(a1, a2, a3)),
                (x, w_km, w_s)),
            f"w4a16_g128.{shape_name}": (
                jax.jit(lambda a1, a2, a3:
                        ops.w4a16_matmul_kmajor(a1, a2, a3, 128)),
                (xb, w_kmg, sg)),
            f"lut4_matmul.{shape_name}": (
                jax.jit(lambda a1, a2, a3, a4:
                        ops.lut4_matmul_kmajor(a1, a2, a3, a4)),
                (aq, a_s, w_km, w_s)),
        }
        for name, (fn, fargs) in rows.items():
            us = _time(fn, *fargs)
            emit(f"kernels.{name}", us, f"gflops={flops/us*1e-3:.2f}")
        if not on_tpu:      # kernel bodies through the interpreter
            us = _time(lambda a1, a2, a3, a4: ops.int4_matmul(
                a1, a2, a3, a4, interpret=True), aq, a_s, wp, w_s)
            emit(f"kernels.int4_matmul_interp.{shape_name}", us,
                 f"gflops={flops/us*1e-3:.2f}")
            us = _time(lambda a1, a2, a3, a4: ops.lut4_matmul(
                a1, a2, a3, a4, interpret=True), aq, a_s, wp, w_s)
            emit(f"kernels.lut4_matmul_interp.{shape_name}", us,
                 f"gflops={flops/us*1e-3:.2f}")
        us = _time(jax.jit(ref.int4_matmul_ref), aq, a_s, wp, w_s)
        emit(f"kernels.int4_matmul_xla.{shape_name}", us,
             f"gflops={flops/us*1e-3:.2f}")

    _maybe_tune(do_tune, on_tpu)


# decode-attention bench geometry: a serving pool provisioned for PA_MAX_CTX
# tokens/row, timed at several *actual* context lengths — the gather path
# always pays the full pool bound, the fused path only the live context.
PA_SHAPE = {"B": 4, "KV": 8, "G": 2, "hd": 64}    # H = 16
PA_MAX_CTX = 1024
PA_CTXS = (128, 512, 1024)
PA_PAGE_SIZES = (4, 16)


def bench_paged_attention(do_tune: bool = False):
    """Fused paged-decode attention vs the paged_read-then-attend baseline
    (tokens/s vs context length at several page sizes), plus flash vs
    chunked prefill.  f32 pools: the serving `cache_dtype="float32"` cell,
    where the dense gather's traffic penalty is fully visible on CPU."""
    from repro.kernels import autotune, ops
    from repro.models.attention import attention_core
    from repro.serving.kv_pages import paged_read

    rng = np.random.default_rng(3)
    B, KV, G, hd = (PA_SHAPE[k] for k in ("B", "KV", "G", "hd"))
    H = KV * G

    def gather_attn(q, pk, pv, tbl, last):
        kf, vf, kpos = paged_read({"tbl": tbl, "k": pk, "v": pv}, last)
        return attention_core(
            q[:, None], kf, vf, q_positions=last[:, None], k_positions=kpos,
            window=0, impl="full", chunk_q=512)

    for ps in PA_PAGE_SIZES:
        pps = PA_MAX_CTX // ps
        P = B * pps + 8
        q = jnp.asarray(rng.standard_normal((B, H, hd)), jnp.float32)
        pk = jnp.asarray(rng.standard_normal((P, ps, KV, hd)), jnp.float32)
        pv = jnp.asarray(rng.standard_normal((P, ps, KV, hd)), jnp.float32)
        tbl = jnp.asarray(rng.permutation(P)[:B * pps].reshape(B, pps),
                          jnp.int32)
        for ctx in PA_CTXS:
            last = jnp.full((B,), ctx - 1, jnp.int32)
            g_us = _time(jax.jit(gather_attn), q, pk, pv, tbl, last)
            f_us = _time(jax.jit(lambda *a: ops.paged_decode_attention(*a)),
                         q, pk, pv, tbl, last)
            tok = lambda us: f"tok_per_s={B / us * 1e6:.0f}"
            emit(f"kernels.paged_attn.gather.ps{ps}.ctx{ctx}", g_us,
                 f"{tok(g_us)};max_ctx={PA_MAX_CTX}")
            emit(f"kernels.paged_attn.fused.ps{ps}.ctx{ctx}", f_us,
                 f"{tok(f_us)};max_ctx={PA_MAX_CTX}")
        # summary row from the ROWS minima (consistent under --repeat,
        # where per-row minima come from different repeats); us=0:
        # informational, not gate material
        longest = PA_CTXS[-1]
        ratio = (ROWS[f"kernels.paged_attn.gather.ps{ps}.ctx{longest}"]["us"]
                 / ROWS[f"kernels.paged_attn.fused.ps{ps}.ctx{longest}"]["us"])
        emit(f"kernels.paged_attn.speedup.ps{ps}", 0.0,
             f"fused_over_gather_at_ctx{longest}={ratio:.2f}x")

        if do_tune:
            from repro.kernels import paged_attention as pa

            on_tpu = jax.default_backend() == "tpu"
            last_t = jnp.full((B,), PA_CTXS[-1] - 1, jnp.int32)

            def make_call(b):
                pp = max(1, b["bk"] // ps)
                if on_tpu:
                    return lambda: pa.paged_decode_attention(
                        q, pk, pv, tbl, last_t, pp=pp, bkv=b["bn"],
                        interpret=False)
                return lambda: pa.paged_decode_attention_xla(
                    q, pk, pv, tbl, last_t, pp=pp)

            blocks, us = autotune.tune(
                "attn.paged_decode", make_call, B, PA_MAX_CTX, H * hd,
                "float32", group_size=ps)
            emit(f"kernels.autotune.attn.paged_decode.ps{ps}", us,
                 f"bk={blocks['bk']};bn={blocks['bn']}")

    # flash prefill vs the chunked-lax.map baseline (in-flight [S, S] work)
    S = 512
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.bfloat16)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    chunked = jax.jit(lambda *a: attention_core(
        a[0], a[1], a[2], q_positions=a[3], k_positions=a[3],
        window=0, impl="chunked", chunk_q=128))
    flash = jax.jit(lambda *a: ops.flash_prefill(a[0], a[1], a[2], a[3], a[3]))
    c_us = _time(chunked, q, k, v, pos)
    f_us = _time(flash, q, k, v, pos)
    emit(f"kernels.paged_attn.prefill_chunked.s{S}", c_us,
         f"tok_per_s={B * S / c_us * 1e6:.0f}")
    emit(f"kernels.paged_attn.prefill_flash.s{S}", f_us,
         f"tok_per_s={B * S / f_us * 1e6:.0f}")

    if do_tune:
        from repro.kernels import paged_attention as pa

        on_tpu = jax.default_backend() == "tpu"

        def make_prefill_call(b):
            if on_tpu:
                return lambda: pa.flash_prefill(
                    q, k, v, pos, pos, bq=b["bm"], bk=b["bk"], bkv=b["bn"],
                    interpret=False)
            return lambda: pa.flash_prefill_xla(q, k, v, pos, pos, bk=b["bk"])

        blocks, us = autotune.tune("attn.prefill", make_prefill_call,
                                   S, S, H * hd, "bfloat16")
        emit(f"kernels.autotune.attn.prefill.s{S}", us,
             f"bm={blocks['bm']};bk={blocks['bk']};bn={blocks['bn']}")


def bench_gemm_backends():
    """Quantized linear through every backend (system view of the paper)."""
    from repro.core.qlinear import QuantConfig, qdense

    rng = np.random.default_rng(1)
    M, K, N = 256, 512, 512
    w = jnp.asarray(rng.standard_normal((K, N), dtype=np.float32)) * 0.05
    x = jnp.asarray(rng.standard_normal((M, K), dtype=np.float32))
    flops = 2 * M * K * N
    y_ref = qdense(w, x, QuantConfig(backend="float"))
    for backend in ("float", "fake_quant", "int_sim", "pallas_int4", "lut4",
                    "w4a16"):
        fn = jax.jit(lambda a, b=backend: qdense(w, a, QuantConfig(backend=b)))
        us = _time(fn, x)
        y = fn(x)
        rel = float(jnp.linalg.norm(y - y_ref) / jnp.linalg.norm(y_ref))
        emit(f"gemm.{backend}", us, f"gflops={flops/us*1e-3:.2f};relerr={rel:.4f}")


def bench_serving():
    """Continuous-batching engine throughput, paged vs contiguous KV, on a
    shared Poisson trace, plus the prefix-cache row: the shared-system-
    prompt scenario served cold vs cached, plus the bucketed-vs-ragged
    step comparison under batch-composition churn (reduced qwen2; see
    EXPERIMENTS.md §Serving / §Prefix caching / §Ragged serving)."""
    from repro.configs import Runtime, ServingConfig, get_config
    from repro.serving.api import bursty_trace, mixed_trace, poisson_trace, \
        run_trace, shared_prefix_trace
    from repro.serving.engine import InferenceEngine, build_params

    cfg = get_config("qwen2-0.5b").reduced()
    rt = Runtime(quant_backend="w4a4_packed", cache_dtype="bfloat16",
                 remat="none", loss_chunk=0)
    trace = poisson_trace(8, 0.5, [8, 16, 32], [8, 16], cfg.vocab, seed=0)
    params = build_params(cfg, rt)
    for layout in ("paged", "contiguous"):
        sv = ServingConfig(layout=layout, max_batch=4, page_size=16,
                           num_pages=48, max_ctx=128)
        engine = InferenceEngine(cfg, rt, sv, params=params)
        engine.warmup([8, 16, 32])
        stats, _ = run_trace(engine, trace)
        us = stats["wall_s"] * 1e6 / max(stats["steps"], 1)
        rc = stats["recompiles"]
        emit(f"serving.{layout}", us,
             f"tok_per_s={stats['decode_tok_per_s']:.2f};"
             f"p50_s={stats['latency_p50_s']:.3f};"
             f"p95_s={stats['latency_p95_s']:.3f};"
             f"preempt={stats['requests_preempted']};"
             f"pool_peak={stats['kv_pages_high_water']};"
             f"recompiles={rc['total']};"
             f"recompiles_steady={rc['steady_state']}")

    sp_trace = shared_prefix_trace(8, 0.5, 32, [8, 16], [8, 16], cfg.vocab,
                                   seed=0)
    for name, cached in (("prefix_cache", True), ("prefix_cold", False)):
        sv = ServingConfig(layout="paged", max_batch=4, page_size=16,
                           num_pages=48, max_ctx=128, prefix_cache=cached)
        engine = InferenceEngine(cfg, rt, sv, params=params)
        # warm the full-prompt buckets (40/48 -> 64) AND the tail buckets a
        # 32-token hit leaves behind (8/16), so neither run absorbs compiles
        engine.warmup([8, 16, 40, 48])
        stats, _ = run_trace(engine, sp_trace)
        us = stats["wall_s"] * 1e6 / max(stats["steps"], 1)
        rc = stats["recompiles"]
        emit(f"serving.{name}", us,
             f"tok_per_s={stats['decode_tok_per_s']:.2f};"
             f"hit_rate={stats['prefix_hit_rate']:.3f};"
             f"prefill_saved={stats['tokens_prefilled_saved']};"
             f"prefill={stats['prefill_tokens']};"
             f"pool_peak={stats['kv_pages_high_water']};"
             f"recompiles={rc['total']};"
             f"recompiles_steady={rc['steady_state']}")

    # bucketed vs ragged serving step under batch-composition churn: mixed
    # (one arrival per step, cycling lengths) and bursty (admission spikes).
    # Short generations keep admissions flowing, so the bucketed engine pays
    # a full-prompt prefill launch plus a decode launch on most steps; the
    # ragged engine runs ONE token-major launch per step regardless of
    # composition, chunking prefills through its token budget (16 here —
    # tuned, see EXPERIMENTS.md §Ragged serving: the auto budget optimizes
    # TTFT, a tighter budget step wall).
    step_traces = {
        "mixed": mixed_trace(16, [16, 32, 64], [2, 4], cfg.vocab, seed=0),
        "bursty": bursty_trace(16, 4, 4, [16, 32, 64], [2, 4], cfg.vocab,
                               seed=0),
    }
    for sc_name, sc_trace in step_traces.items():
        for mode in ("bucketed", "ragged"):
            sv = ServingConfig(layout="paged", max_batch=4, page_size=16,
                               num_pages=48, max_ctx=128, step=mode,
                               token_budget=16 if mode == "ragged" else 0)
            engine = InferenceEngine(cfg, rt, sv, params=params)
            engine.warmup([16, 32, 64])
            stats, _ = run_trace(engine, sc_trace)
            us = stats["wall_s"] * 1e6 / max(stats["steps"], 1)
            rc = stats["recompiles"]
            emit(f"serving.step_{mode}_{sc_name}", us,
                 f"tok_per_s={stats['decode_tok_per_s']:.2f};"
                 f"padding_wasted={stats['padding_tokens_wasted']};"
                 f"token_util={stats['token_utilization']:.3f};"
                 f"steps={stats['steps']};"
                 f"recompiles={rc['total']};"
                 f"recompiles_steady={rc['steady_state']}")


def bench_sensitivity():
    """Per-site quantization sensitivity sweep (reduced qwen2, 2 layers so
    block-indexed groups have layers to differ on): flip one site group to
    float at a time, report logits-MSE vs the full-float reference and the
    improvement over the uniform-W4 plan.  Feeds the preset choices in
    core.quant_plan (see EXPERIMENTS.md §Mixed precision)."""
    from repro.configs import get_config
    from repro.launch.sensitivity import sensitivity_sweep

    cfg = get_config("qwen2-0.5b").reduced(n_layers=2)
    out = sensitivity_sweep(cfg, seed=0)
    emit("sensitivity.uniform_w4", 0.0,
         f"mse={out['uniform_mse_vs_float']:.3e}")
    for row in out["per_site"]:
        emit(f"sensitivity.{row['site']}", 0.0,
             f"mse={row['mse_vs_float']:.3e};"
             f"delta={row['delta_vs_uniform']:.3e}")
    # uniform-plan backend comparison (int_sim / lut4 / w4a16): lut4 must
    # equal int_sim exactly — same integer math, different kernel
    for row in out["backends"]:
        emit(f"sensitivity.backend.{row['backend']}", 0.0,
             f"mse={row['mse_vs_float']:.3e}")


def check_recompiles(rows: dict) -> list:
    """Steady-state recompile gate over the emitted rows: any serving row
    carrying ``recompiles_steady=N`` with N > 0 fails the run.  This is the
    perf gate's blind spot closed — a change can keep wall time flat on a
    short bench while silently recompiling every bucket mid-run, and only
    this counter (observability.jit_watch) sees it."""
    import re

    failures = []
    for name, row in sorted(rows.items()):
        m = re.search(r"recompiles_steady=(\d+)", row["derived"])
        if m and int(m.group(1)) > 0:
            failures.append(f"{name}: {m.group(1)} steady-state "
                            f"recompile(s) — buckets recompiled mid-run")
    return failures


def _gate_rows(rows: dict, base: dict):
    """(name, base_us, cur_us) for every row both sides can gate on."""
    out = []
    for name, entry in sorted(base.items()):
        if name not in rows or "_interp" in name:
            continue
        if not name.startswith(("kernels.", "gemm.", "serving.")):
            continue
        if name.startswith("kernels.autotune."):
            continue
        base_us, cur_us = entry["us"], rows[name]["us"]
        if base_us < GATE_FLOOR_US or cur_us < GATE_FLOOR_US:
            continue
        out.append((name, base_us, cur_us))
    return out


def check_regression(rows: dict, baseline_path: str, tol: float) -> list:
    """Host-normalized perf gate.

    Host speed is estimated as the *median* of per-row cur/base ratios —
    robust: if every row moves together it's the machine, and the median
    cancels it; a single regressed row stands out against the median.  A
    row whose median-normalized ratio exceeds `tol` fails the gate.
    Returns the list of failure strings."""
    with open(baseline_path) as f:
        data = json.load(f)
    base = data["rows"]
    base_backend = data.get("backend")
    here = jax.default_backend()
    if base_backend and base_backend != here:
        return [f"baseline was measured on backend {base_backend!r} but "
                f"this run is {here!r}; per-row CPU/TPU ratios are not "
                f"comparable — regenerate the baseline on a matching host"]
    gate = _gate_rows(rows, base)
    if not gate:
        return ["no gateable rows shared with the baseline"]
    host = float(np.median([cur / b for _, b, cur in gate]))
    print(f"gate: host-speed factor {host:.2f}x vs baseline "
          f"({len(gate)} rows)")
    failures = []
    for name, base_us, cur_us in gate:
        ratio = (cur_us / base_us) / host
        status = "FAIL" if ratio > tol else "ok"
        print(f"gate.{name}: normalized {ratio:.2f}x vs baseline [{status}]")
        if ratio > tol:
            failures.append(f"{name}: {ratio:.2f}x > {tol:.2f}x "
                            f"({cur_us:.0f}us vs {base_us:.0f}us baseline)")
    return failures


SECTIONS = {
    "table2": bench_table2,
    "table3": bench_table3,
    "fig5": bench_fig5,
    "pipeline": bench_pipeline,
    "kernels": bench_kernels,
    "paged_attn": bench_paged_attention,
    "gemm": bench_gemm_backends,
    "serving": bench_serving,
    "sensitivity": bench_sensitivity,
}


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("sections", nargs="*", default=[],
                   help=f"sections to run (default: all of {list(SECTIONS)})")
    p.add_argument("--out", help="write emitted rows to this JSON file")
    p.add_argument("--baseline", help="gate against this committed JSON")
    p.add_argument("--gate-tol", type=float, default=1.25,
                   help="normalized regression threshold (default 1.25)")
    p.add_argument("--autotune", action="store_true",
                   help="run the kernel block-size search (implied on TPU)")
    p.add_argument("--repeat", type=int, default=1,
                   help="run the timed sections N times, keep per-row min "
                        "(smooths CI-runner noise)")
    args = p.parse_args(argv)

    from repro.kernels import autotune

    unknown = [s for s in args.sections if s not in SECTIONS]
    if unknown:
        p.error(f"unknown sections {unknown}; choose from {list(SECTIONS)}")
    sections = args.sections or list(SECTIONS)
    if args.baseline and "gemm" not in sections:
        sections.append("gemm")          # the gate's normalizer row
    do_tune = args.autotune or autotune.should_tune()
    for rep in range(max(1, args.repeat)):
        for name in sections:
            if name == "kernels":
                bench_kernels(do_tune=do_tune and rep == 0)
            elif name == "paged_attn":
                bench_paged_attention(do_tune=do_tune and rep == 0)
            else:
                SECTIONS[name]()

    if args.out:
        with open(args.out, "w") as f:
            json.dump({"backend": jax.default_backend(), "rows": ROWS},
                      f, indent=1, sort_keys=True)
        print(f"wrote {len(ROWS)} rows -> {args.out}")
    recompile_failures = check_recompiles(ROWS)
    if recompile_failures:
        print("RECOMPILE GATE FAILED:\n  "
              + "\n  ".join(recompile_failures), file=sys.stderr)
        return 1
    if args.baseline:
        failures = check_regression(ROWS, args.baseline, args.gate_tol)
        if failures:
            print("PERF GATE FAILED:\n  " + "\n  ".join(failures),
                  file=sys.stderr)
            return 1
        print("perf gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
