"""Benchmark harness — one function per paper table/figure + kernel and
system benchmarks.  Prints ``name,us_per_call,derived`` CSV rows.

  table2   -> paper Table II  (resources: LUTs / CARRY4 per design)
  table3   -> paper Table III (critical-path delay, logic/net split)
  fig5     -> paper Fig. 5    (area x delay frontier points)
  pipeline -> paper §VI       (pipelined Fmax)
  kernels  -> TPU-adaptation kernels: us/call + GOP/s vs the jnp oracle
  gemm     -> quantized-GEMM backends (the "multiplier array" system view)
  serving  -> continuous-batching engine: paged vs contiguous KV tokens/s
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, reps=5, warmup=2) -> float:
    """Median wall-time per call in microseconds."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def bench_table2():
    from repro.core import (
        PUBLISHED_ROWS, build_acc_mult4, build_lm_mult4,
        build_proposed_mult4, resources,
    )

    ours = {
        "proposed": resources(build_proposed_mult4()),
        "lm": resources(build_lm_mult4()),
        "acc_ullah": resources(build_acc_mult4()),
    }
    for name, row in PUBLISHED_ROWS.items():
        o = ours.get(name)
        derived = (f"luts={o['luts']};carry4={o['carry4']};"
                   f"pub_luts={row['luts']};pub_carry4={row['carry4']}"
                   if o else f"pub_luts={row['luts']};pub_carry4={row['carry4']}")
        print(f"table2.{name},0.0,{derived}")


def bench_table3():
    from repro.core import (
        PUBLISHED_ROWS, analyze, build_acc_mult4, build_lm_mult4,
        build_proposed_mult4,
    )

    ours = {
        "proposed": analyze(build_proposed_mult4()),
        "lm": analyze(build_lm_mult4()),
        "acc_ullah": analyze(build_acc_mult4()),
    }
    for name, row in PUBLISHED_ROWS.items():
        if row.get("cpd") is None and name not in ours:
            continue
        o = ours.get(name)
        parts = []
        if o:
            parts.append(f"cpd={o['cpd']};logic={o['logic']};net={o['net']}")
        if row.get("cpd") is not None:
            parts.append(f"pub_cpd={row['cpd']}")
        print(f"table3.{name},0.0,{';'.join(parts)}")


def bench_fig5():
    from repro.core import PUBLISHED_ROWS, analyze, build_proposed_mult4

    t = analyze(build_proposed_mult4())
    for name, row in PUBLISHED_ROWS.items():
        if row.get("cpd") is None:
            continue
        print(f"fig5.{name},0.0,luts={row['luts']};cpd={row['cpd']}")
    print(f"fig5.proposed_ours,0.0,luts=11;cpd={t['cpd']}")


def bench_pipeline():
    from repro.core.pipeline_mult import pipelined_report

    rep = pipelined_report()
    print(f"pipeline.proposed,0.0,"
          f"fmax_mhz={rep['fmax_mhz']};unpipelined={rep['unpipelined_fmax_mhz']};"
          f"stage1={rep['stage1_ns']};stage2={rep['stage2_ns']}")


def bench_kernels():
    from repro.core.quant import pack_int4
    from repro.kernels import ops, ref

    rng = np.random.default_rng(0)
    # elementwise LUT multiplier array, 1M elements
    n = 1 << 20
    a = jnp.asarray(rng.integers(-8, 8, size=n, dtype=np.int8))
    b = jnp.asarray(rng.integers(-8, 8, size=n, dtype=np.int8))
    for strat in ("onehot", "take"):
        fn = jax.jit(lambda x, y, s=strat: ops.mul4(x, y, strategy=s))
        us = _time(fn, a, b)
        print(f"kernels.lut_mul4_{strat},{us:.1f},gops={n/us*1e-3:.2f}")
    fn = jax.jit(ref.mul4_ref)
    us = _time(fn, a, b)
    print(f"kernels.mul4_xla_ref,{us:.1f},gops={n/us*1e-3:.2f}")

    # netlist bit-sim multiplier array (the paper's circuit, vectorized)
    from repro.core import build_proposed_mult4
    nl = build_proposed_mult4()
    au = jnp.asarray(rng.integers(0, 16, size=n, dtype=np.uint8))
    bu = jnp.asarray(rng.integers(0, 16, size=n, dtype=np.uint8))
    fn = jax.jit(lambda x, y: nl(x, y))
    us = _time(fn, au, bu)
    print(f"kernels.netlist_sim,{us:.1f},gops={n/us*1e-3:.2f}")

    # int4 matmul kernel vs oracle
    M = K = N = 512
    aq = jnp.asarray(rng.integers(-8, 8, size=(M, K), dtype=np.int8))
    a_s = jnp.ones((M, 1), jnp.float32)
    wq = jnp.asarray(rng.integers(-8, 8, size=(K, N), dtype=np.int8))
    w_s = jnp.ones((1, N), jnp.float32)
    wp = pack_int4(wq, -1)
    flops = 2 * M * K * N
    us = _time(lambda: ops.int4_matmul(aq, a_s, wp, w_s))
    print(f"kernels.int4_matmul_pallas,{us:.1f},gflops={flops/us*1e-3:.2f}")
    us = _time(jax.jit(lambda: ref.int4_matmul_ref(aq, a_s, wp, w_s)))
    print(f"kernels.int4_matmul_xla,{us:.1f},gflops={flops/us*1e-3:.2f}")


def bench_gemm_backends():
    """Quantized linear through every backend (system view of the paper)."""
    from repro.core.qlinear import QuantConfig, qdense

    rng = np.random.default_rng(1)
    M, K, N = 256, 512, 512
    w = jnp.asarray(rng.standard_normal((K, N), dtype=np.float32)) * 0.05
    x = jnp.asarray(rng.standard_normal((M, K), dtype=np.float32))
    flops = 2 * M * K * N
    y_ref = qdense(w, x, QuantConfig(backend="float"))
    for backend in ("float", "fake_quant", "int_sim", "w4a16"):
        fn = jax.jit(lambda a, b=backend: qdense(w, a, QuantConfig(backend=b)))
        us = _time(fn, x)
        y = fn(x)
        rel = float(jnp.linalg.norm(y - y_ref) / jnp.linalg.norm(y_ref))
        print(f"gemm.{backend},{us:.1f},gflops={flops/us*1e-3:.2f};relerr={rel:.4f}")


def bench_serving():
    """Continuous-batching engine throughput, paged vs contiguous KV, on a
    shared Poisson trace (reduced qwen2; see EXPERIMENTS.md §Serving)."""
    from repro.configs import Runtime, ServingConfig, get_config
    from repro.serving.api import poisson_trace, run_trace
    from repro.serving.engine import InferenceEngine, build_params

    cfg = get_config("qwen2-0.5b").reduced()
    rt = Runtime(quant_backend="w4a4_packed", cache_dtype="bfloat16",
                 remat="none", loss_chunk=0)
    trace = poisson_trace(8, 0.5, [8, 16, 32], [8, 16], cfg.vocab, seed=0)
    params = build_params(cfg, rt)
    for layout in ("paged", "contiguous"):
        sv = ServingConfig(layout=layout, max_batch=4, page_size=16,
                           num_pages=48, max_ctx=128)
        engine = InferenceEngine(cfg, rt, sv, params=params)
        engine.warmup([8, 16, 32])
        stats, _ = run_trace(engine, trace)
        us = stats["wall_s"] * 1e6 / max(stats["steps"], 1)
        print(f"serving.{layout},{us:.1f},"
              f"tok_per_s={stats['decode_tok_per_s']:.2f};"
              f"p50_s={stats['latency_p50_s']:.3f};"
              f"p95_s={stats['latency_p95_s']:.3f};"
              f"preempt={stats['requests_preempted']}")


def main() -> None:
    bench_table2()
    bench_table3()
    bench_fig5()
    bench_pipeline()
    bench_kernels()
    bench_gemm_backends()
    bench_serving()


if __name__ == "__main__":
    main()
