"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from reports/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.roofline_tables [--dir reports/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def load(dir_):
    reps = []
    for fn in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(fn) as f:
            reps.append(json.load(f))
    return reps


def dryrun_table(reps):
    rows = ["| arch | shape | mesh | status | HBM/dev GiB | collectives/dev GiB | cross-pod GiB |",
            "|---|---|---|---|---|---|---|"]
    for r in reps:
        mesh = "2x16x16" if r.get("multi_pod") else "16x16"
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | {mesh} | skipped¹ | — | — | — |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {mesh} | FAILED | — | — | — |")
            continue
        hbm = fmt_bytes(r["memory"]["total_hbm_bytes"])
        if "roofline" in r:
            coll = fmt_bytes(r["roofline"]["collective_bytes_per_dev"])
            xp = fmt_bytes(r["roofline"]["cross_pod_bytes_per_dev"])
        else:
            coll = xp = "—"
        rows.append(f"| {r['arch']} | {r['shape']} | {mesh} | ok | {hbm} | {coll} | {xp} |")
    return "\n".join(rows)


def roofline_table(reps):
    rows = ["| arch | shape | compute s | memory s | collective s | bound | MODEL_FLOPs/dev | useful ratio | note |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in reps:
        if r.get("multi_pod") or r["status"] != "ok" or "roofline" not in r:
            continue
        t = r["roofline"]
        note = ""
        rows.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3e} | "
            f"{t['memory_s']:.3e} | {t['collective_s']:.3e} | {t['bound']} | "
            f"{t['model_flops_per_dev']:.3e} | "
            f"{t['useful_flop_ratio']:.2f} | {note} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="reports/dryrun")
    args = ap.parse_args()
    reps = load(args.dir)
    print("## Dry-run matrix\n")
    print(dryrun_table(reps))
    print("\n¹ long_500k requires sub-quadratic attention (DESIGN.md §4).\n")
    print("## Roofline (single-pod 16x16)\n")
    print(roofline_table(reps))


if __name__ == "__main__":
    main()
