"""Request scheduler: admission queue, continuous batching, preemption.

Requests join the running set at decode-step boundaries (admission triggers a
prefill), leave it the step they finish, and are preempted back to the front
of the queue when the page pool runs dry.  Preemption is recompute-style: the
victim's pages are released and on re-admission the prefix (prompt + tokens
generated so far) is re-prefilled — no KV swap-out traffic, the same policy
vLLM defaults to for short sequences.  With the prefix cache on, the victim's
full pages usually survive in the warm pool, so admission re-acquires them
and only the uncached tail is actually recomputed.  Resume is lossless for
greedy decode with non-lossy cache dtypes (the bf16 cache stores K/V
exactly); with an int8/int4 KV cache the recomputed prefix attends in full
precision, so a resumed request's tokens may legitimately differ from an
uninterrupted run (prefix-cache hits over a lossy pool dequantize, with the
same caveat).

Determinism: slots are assigned lowest-free-first, the decode batch is the
running set in slot order, and the preemption victim is always the
latest-admitted request — so a trace replayed against either KV layout makes
identical scheduling decisions (the engine's bit-exactness harness relies on
this).
"""

from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from typing import Dict, List, Optional

import numpy as np

from repro.observability.metrics import NULL_REGISTRY

WAITING, RUNNING, FINISHED = "waiting", "running", "finished"

# terminal request outcomes: every retired request carries exactly one
OK, CANCELLED, TIMEOUT, SHED, ERROR = \
    "ok", "cancelled", "timeout", "shed", "error"
OUTCOMES = (OK, CANCELLED, TIMEOUT, SHED, ERROR)


class ShedError(RuntimeError):
    """Typed load-shedding rejection: the bounded admission queue
    (``ServingConfig.max_queue``) is full.  The request was never queued;
    backpressure belongs to the caller (retry, spill to another replica,
    or surface a 429)."""


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # int32 [L]
    max_new: int
    arrival: float = 0.0                # engine-clock time the request exists
    eos_id: Optional[int] = None
    deadline: Optional[float] = None    # absolute engine-clock deadline; the
                                        # step-boundary sweep retires overdue
                                        # requests with outcome=timeout
    # -- runtime state ----------------------------------------------------
    state: str = WAITING
    outcome: Optional[str] = None       # one of OUTCOMES once retired
    slot: int = -1
    tokens: List[int] = dataclasses.field(default_factory=list)  # generated
    n_cached: int = 0                   # tokens written to the KV cache
    decoding: bool = False              # emitted since (re-)admission: the
                                        # ragged planner feeds exactly one
                                        # token/step once this flips
    n_preempts: int = 0
    admit_seq: int = -1                 # admission order (preemption victim key)
    t_visible: Optional[float] = None
    t_admit: Optional[float] = None
    t_first: Optional[float] = None
    t_finish: Optional[float] = None

    @property
    def prefix(self) -> np.ndarray:
        """Prompt + generated-so-far: what a (re-)prefill must process."""
        return np.concatenate(
            [self.prompt, np.asarray(self.tokens, np.int32)])

    @property
    def target_len(self) -> int:
        return len(self.prompt) + self.max_new

    @property
    def done(self) -> bool:
        if len(self.tokens) >= self.max_new:
            return True
        return bool(self.tokens) and self.tokens[-1] == self.eos_id


class Scheduler:
    """Owns the waiting queue and the running set; talks to a KV manager
    (PagedKVCacheManager or ContinuousKVCache) for capacity decisions."""

    def __init__(self, kv_manager, max_batch: int, metrics=None,
                 max_queue: int = 0):
        self.kv = kv_manager
        self.max_batch = max_batch
        # bounded admission queue: submit() sheds (typed ShedError) once
        # this many requests wait; 0 = unbounded (the pre-hardening default)
        self.max_queue = max_queue
        # telemetry registry (observability.metrics): admission / resume /
        # preemption counters land here; queue-depth and running-set gauges
        # are sampled by the engine at step boundaries
        self.metrics = metrics if metrics is not None else NULL_REGISTRY
        self.waiting: deque = deque()
        self.running: Dict[int, Request] = {}        # rid -> Request
        self._free_slots: List[int] = list(range(max_batch))
        heapq.heapify(self._free_slots)
        self._admit_counter = 0
        self.n_preemptions = 0

    # ----------------------------------------------------------- submit --
    def submit(self, req: Request) -> None:
        if not self.kv.fits_alone(req.target_len):
            raise ValueError(
                f"request {req.rid}: prompt {len(req.prompt)} + max_new "
                f"{req.max_new} exceeds serving capacity "
                f"({self.kv.capacity_desc()})")
        if self.max_queue and len(self.waiting) >= self.max_queue:
            raise ShedError(
                f"request {req.rid}: admission queue full "
                f"({self.max_queue} waiting) — shedding")
        self.waiting.append(req)

    # -------------------------------------------------------- admission --
    def admit(self, now: float) -> List[Request]:
        """Admit queue-head requests that have arrived and fit (a free batch
        slot + pages for prefix and the first decode write).  FIFO: a stuck
        head blocks later arrivals — no starvation.

        With the prefix cache on, admission (`kv.admit_request`) first
        matches the longest cached page-aligned prefix: the request starts
        at ``n_cached = hit_len`` over shared (refcounted) pages and the
        engine prefills only the tail — this is also what makes
        preempt→resume re-prefill just the uncached suffix, since a
        victim's registered pages outlive its release.  Admission is
        all-or-nothing: a request that doesn't fit leaves no holds, no
        counter bumps, and no LRU churn behind.

        Deadline awareness: requests carrying a deadline are considered
        earliest-deadline-first, ahead of the deadline-less FIFO tail — the
        request whose SLO is most at risk gets the next free slot.  The
        ordering depends only on (deadline, queue position), both replayed
        identically across engines, so determinism of the compare harness
        is preserved; with no deadlines in play the order is exactly the
        old FIFO."""
        admitted = []
        for req in self._admission_order():
            if not self._free_slots:
                break
            if req.arrival > now:
                continue                # not arrived yet; others may have
            prefix = req.prefix
            hit = self.kv.admit_request(req.rid, prefix, len(prefix) + 1)
            if hit is None:
                break                   # capacity-blocked head: no skip-ahead
            self.waiting.remove(req)
            req.n_cached = hit
            req.decoding = False
            req.slot = heapq.heappop(self._free_slots)
            req.state = RUNNING
            req.t_admit = now
            req.admit_seq = self._admit_counter
            self._admit_counter += 1
            self.running[req.rid] = req
            admitted.append(req)
            self.metrics.counter("sched_admissions_total",
                                 "requests admitted to the running set").inc()
            if req.n_preempts:
                self.metrics.counter(
                    "sched_resumes_total",
                    "admissions of previously-preempted requests").inc()
        return admitted

    def _admission_order(self) -> List[Request]:
        """Deadline-carrying waiters earliest-deadline-first, then the rest
        in queue position (preemption victims appendleft, so they keep
        resuming before new arrivals)."""
        if not any(r.deadline is not None for r in self.waiting):
            return list(self.waiting)                # pure FIFO, no sort
        pos = {id(r): i for i, r in enumerate(self.waiting)}
        return sorted(self.waiting,
                      key=lambda r: ((0, r.deadline) if r.deadline is not None
                                     else (1, 0.0), pos[id(r)]))

    # ---------------------------------------------------- cancel / expire --
    def _evict_running(self, req: Request) -> None:
        """Take a running request out of the batch, releasing its pages
        (refcounted — shared prefix pages stay warm and hittable) and its
        batch slot."""
        self.kv.release(req.rid)
        heapq.heappush(self._free_slots, req.slot)
        del self.running[req.rid]
        req.slot = -1

    def _retire_aborted(self, req: Request, now: float, outcome: str) -> None:
        req.state = FINISHED
        req.outcome = outcome
        req.t_finish = now

    def cancel(self, rid: int, now: float,
               outcome: str = CANCELLED) -> Optional[Request]:
        """Abort a queued or running request.  Queued requests simply leave
        the waiting deque; running ones release their pages and slot like a
        preemption that never resumes.  Returns the retired request, or
        None when rid is unknown to the scheduler (already finished)."""
        for req in self.waiting:
            if req.rid == rid:
                self.waiting.remove(req)
                self._retire_aborted(req, now, outcome)
                return req
        req = self.running.get(rid)
        if req is None:
            return None
        self._evict_running(req)
        self._retire_aborted(req, now, outcome)
        return req

    def expire(self, now: float) -> List[Request]:
        """Deadline sweep at a step boundary: retire every waiting or
        running request whose absolute deadline has passed with
        outcome=timeout (running victims release pages like a cancel).
        Returns the expired requests so the engine can observe them."""
        expired = []
        for req in [r for r in self.waiting
                    if r.deadline is not None and r.deadline <= now]:
            self.waiting.remove(req)
            self._retire_aborted(req, now, TIMEOUT)
            expired.append(req)
        for req in [r for r in self.running.values()
                    if r.deadline is not None and r.deadline <= now]:
            self._evict_running(req)
            self._retire_aborted(req, now, TIMEOUT)
            expired.append(req)
        return expired

    # -------------------------------------------------------- preemption --
    def _preempt(self, victim: Request) -> None:
        self._evict_running(victim)
        victim.state = WAITING
        # n_cached is re-derived at admission (admit_request): a victim
        # whose registered pages survive in the warm pool re-admits at its
        # hit length instead of re-prefilling the whole prefix.  Zero here
        # only states "nothing owned while waiting".
        victim.n_cached = 0
        victim.decoding = False
        victim.n_preempts += 1
        self.n_preemptions += 1
        self.metrics.counter("sched_preemptions_total",
                             "requests evicted on pool exhaustion").inc()
        self.waiting.appendleft(victim)   # resumes before new arrivals

    def ensure_decode(self) -> List[Request]:
        """Guarantee every running request has a page for this step's KV
        write; evict latest-admitted requests until the survivors fit.
        Returns the preempted requests."""
        preempted = []
        for req in sorted(self.running.values(), key=lambda r: r.admit_seq):
            while req.rid in self.running \
                    and not self.kv.ensure(req.rid, req.n_cached + 1):
                victim = max(self.running.values(), key=lambda r: r.admit_seq)
                if victim is req and len(self.running) == 1:
                    raise RuntimeError(
                        f"request {req.rid} cannot fit alone "
                        f"(n_cached={req.n_cached}); pool too small")
                self._preempt(victim)
                preempted.append(victim)
        return preempted

    # ------------------------------------------------------------ finish --
    def finish(self, req: Request, now: float) -> None:
        self._evict_running(req)
        req.state = FINISHED
        req.outcome = OK
        req.t_finish = now

    # ------------------------------------------------------------- batch --
    def batch(self) -> List[Request]:
        """The decode batch: running requests in slot order."""
        return sorted(self.running.values(), key=lambda r: r.slot)

    def plan_tokens(self, budget: int) -> List:
        """Token-budget plan for one ragged step: ``[(req, start, n)]``
        where the step feeds ``req.prefix[start:start+n]`` at positions
        ``start..start+n-1``.

        Decode tokens come first — every request that has emitted since
        admission gets its single newest token (in slot order, matching
        ``batch()``) — then prefill-phase requests chunk their remaining
        prefix into whatever budget is left, oldest admission first (FIFO,
        like the bucketed engine prefills admissions in arrival order).  A
        prefill that gets no budget this step simply waits; determinism
        holds because the plan depends only on (running set, n_cached),
        both replayed identically across engines."""
        plan, used = [], 0
        for req in self.batch():
            if req.decoding and used < budget:
                plan.append((req, req.n_cached, 1))
                used += 1
        for req in sorted((r for r in self.running.values() if not r.decoding),
                          key=lambda r: r.admit_seq):
            if used >= budget:
                break
            n = min(len(req.prefix) - req.n_cached, budget - used)
            if n > 0:
                plan.append((req, req.n_cached, n))
                used += n
        return plan

    @property
    def idle(self) -> bool:
        return not self.waiting and not self.running

    # -------------------------------------------------------- invariants --
    def check_invariants(self) -> None:
        """Structural scheduler invariants, assertable after any event (the
        chaos harness and the allocator property test call this after every
        step/cancel/expire/preempt):

          * running slots are unique, in range, and together with the free
            heap partition [0, max_batch)
          * waiting and running sets are disjoint; states match membership
          * every running request's cached tokens are covered by its page
            allocation; waiting requests hold no pages
        """
        slots = [r.slot for r in self.running.values()]
        assert len(set(slots)) == len(slots), f"duplicate slots {slots}"
        free = set(self._free_slots)
        assert len(free) == len(self._free_slots), "duplicate free slots"
        assert free | set(slots) == set(range(self.max_batch)), \
            f"slot partition broken: free={free} running={slots}"
        w_rids = [r.rid for r in self.waiting]
        assert len(set(w_rids)) == len(w_rids), "rid queued twice"
        assert not set(w_rids) & set(self.running), \
            "rid both waiting and running"
        pages = getattr(self.kv, "pages", None)
        for req in self.waiting:
            assert req.state == WAITING, (req.rid, req.state)
            if pages is not None:
                assert req.rid not in pages, \
                    f"waiting rid {req.rid} still holds pages"
        for req in self.running.values():
            assert req.state == RUNNING, (req.rid, req.state)
            if pages is not None:
                assert (self.kv.pages_for(req.n_cached)
                        <= len(pages.get(req.rid, []))), \
                    f"rid {req.rid} cached {req.n_cached} tokens beyond " \
                    f"its {len(pages.get(req.rid, []))}-page allocation"
