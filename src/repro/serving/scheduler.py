"""Request scheduler: admission queue, continuous batching, preemption.

Requests join the running set at decode-step boundaries (admission triggers a
prefill), leave it the step they finish, and are preempted back to the front
of the queue when the page pool runs dry.  Preemption is recompute-style: the
victim's pages are released and on re-admission the prefix (prompt + tokens
generated so far) is re-prefilled — no KV swap-out traffic, the same policy
vLLM defaults to for short sequences.  With the prefix cache on, the victim's
full pages usually survive in the warm pool, so admission re-acquires them
and only the uncached tail is actually recomputed.  Resume is lossless for
greedy decode with non-lossy cache dtypes (the bf16 cache stores K/V
exactly); with an int8/int4 KV cache the recomputed prefix attends in full
precision, so a resumed request's tokens may legitimately differ from an
uninterrupted run (prefix-cache hits over a lossy pool dequantize, with the
same caveat).

Determinism: slots are assigned lowest-free-first, the decode batch is the
running set in slot order, and the preemption victim is always the
latest-admitted request — so a trace replayed against either KV layout makes
identical scheduling decisions (the engine's bit-exactness harness relies on
this).
"""

from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from typing import Dict, List, Optional

import numpy as np

from repro.observability.metrics import NULL_REGISTRY

WAITING, RUNNING, FINISHED = "waiting", "running", "finished"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # int32 [L]
    max_new: int
    arrival: float = 0.0                # engine-clock time the request exists
    eos_id: Optional[int] = None
    # -- runtime state ----------------------------------------------------
    state: str = WAITING
    slot: int = -1
    tokens: List[int] = dataclasses.field(default_factory=list)  # generated
    n_cached: int = 0                   # tokens written to the KV cache
    decoding: bool = False              # emitted since (re-)admission: the
                                        # ragged planner feeds exactly one
                                        # token/step once this flips
    n_preempts: int = 0
    admit_seq: int = -1                 # admission order (preemption victim key)
    t_visible: Optional[float] = None
    t_admit: Optional[float] = None
    t_first: Optional[float] = None
    t_finish: Optional[float] = None

    @property
    def prefix(self) -> np.ndarray:
        """Prompt + generated-so-far: what a (re-)prefill must process."""
        return np.concatenate(
            [self.prompt, np.asarray(self.tokens, np.int32)])

    @property
    def target_len(self) -> int:
        return len(self.prompt) + self.max_new

    @property
    def done(self) -> bool:
        if len(self.tokens) >= self.max_new:
            return True
        return bool(self.tokens) and self.tokens[-1] == self.eos_id


class Scheduler:
    """Owns the waiting queue and the running set; talks to a KV manager
    (PagedKVCacheManager or ContinuousKVCache) for capacity decisions."""

    def __init__(self, kv_manager, max_batch: int, metrics=None):
        self.kv = kv_manager
        self.max_batch = max_batch
        # telemetry registry (observability.metrics): admission / resume /
        # preemption counters land here; queue-depth and running-set gauges
        # are sampled by the engine at step boundaries
        self.metrics = metrics if metrics is not None else NULL_REGISTRY
        self.waiting: deque = deque()
        self.running: Dict[int, Request] = {}        # rid -> Request
        self._free_slots: List[int] = list(range(max_batch))
        heapq.heapify(self._free_slots)
        self._admit_counter = 0
        self.n_preemptions = 0

    # ----------------------------------------------------------- submit --
    def submit(self, req: Request) -> None:
        if not self.kv.fits_alone(req.target_len):
            raise ValueError(
                f"request {req.rid}: prompt {len(req.prompt)} + max_new "
                f"{req.max_new} exceeds serving capacity "
                f"({self.kv.capacity_desc()})")
        self.waiting.append(req)

    # -------------------------------------------------------- admission --
    def admit(self, now: float) -> List[Request]:
        """Admit queue-head requests that have arrived and fit (a free batch
        slot + pages for prefix and the first decode write).  FIFO: a stuck
        head blocks later arrivals — no starvation.

        With the prefix cache on, admission (`kv.admit_request`) first
        matches the longest cached page-aligned prefix: the request starts
        at ``n_cached = hit_len`` over shared (refcounted) pages and the
        engine prefills only the tail — this is also what makes
        preempt→resume re-prefill just the uncached suffix, since a
        victim's registered pages outlive its release.  Admission is
        all-or-nothing: a request that doesn't fit leaves no holds, no
        counter bumps, and no LRU churn behind."""
        admitted = []
        while self.waiting and self._free_slots:
            req = self.waiting[0]
            if req.arrival > now:
                break
            prefix = req.prefix
            hit = self.kv.admit_request(req.rid, prefix, len(prefix) + 1)
            if hit is None:
                break
            self.waiting.popleft()
            req.n_cached = hit
            req.decoding = False
            req.slot = heapq.heappop(self._free_slots)
            req.state = RUNNING
            req.t_admit = now
            req.admit_seq = self._admit_counter
            self._admit_counter += 1
            self.running[req.rid] = req
            admitted.append(req)
            self.metrics.counter("sched_admissions_total",
                                 "requests admitted to the running set").inc()
            if req.n_preempts:
                self.metrics.counter(
                    "sched_resumes_total",
                    "admissions of previously-preempted requests").inc()
        return admitted

    # -------------------------------------------------------- preemption --
    def _preempt(self, victim: Request) -> None:
        self.kv.release(victim.rid)
        heapq.heappush(self._free_slots, victim.slot)
        del self.running[victim.rid]
        victim.slot = -1
        victim.state = WAITING
        # n_cached is re-derived at admission (admit_request): a victim
        # whose registered pages survive in the warm pool re-admits at its
        # hit length instead of re-prefilling the whole prefix.  Zero here
        # only states "nothing owned while waiting".
        victim.n_cached = 0
        victim.decoding = False
        victim.n_preempts += 1
        self.n_preemptions += 1
        self.metrics.counter("sched_preemptions_total",
                             "requests evicted on pool exhaustion").inc()
        self.waiting.appendleft(victim)   # resumes before new arrivals

    def ensure_decode(self) -> List[Request]:
        """Guarantee every running request has a page for this step's KV
        write; evict latest-admitted requests until the survivors fit.
        Returns the preempted requests."""
        preempted = []
        for req in sorted(self.running.values(), key=lambda r: r.admit_seq):
            while req.rid in self.running \
                    and not self.kv.ensure(req.rid, req.n_cached + 1):
                victim = max(self.running.values(), key=lambda r: r.admit_seq)
                if victim is req and len(self.running) == 1:
                    raise RuntimeError(
                        f"request {req.rid} cannot fit alone "
                        f"(n_cached={req.n_cached}); pool too small")
                self._preempt(victim)
                preempted.append(victim)
        return preempted

    # ------------------------------------------------------------ finish --
    def finish(self, req: Request, now: float) -> None:
        self.kv.release(req.rid)
        heapq.heappush(self._free_slots, req.slot)
        del self.running[req.rid]
        req.slot = -1
        req.state = FINISHED
        req.t_finish = now

    # ------------------------------------------------------------- batch --
    def batch(self) -> List[Request]:
        """The decode batch: running requests in slot order."""
        return sorted(self.running.values(), key=lambda r: r.slot)

    def plan_tokens(self, budget: int) -> List:
        """Token-budget plan for one ragged step: ``[(req, start, n)]``
        where the step feeds ``req.prefix[start:start+n]`` at positions
        ``start..start+n-1``.

        Decode tokens come first — every request that has emitted since
        admission gets its single newest token (in slot order, matching
        ``batch()``) — then prefill-phase requests chunk their remaining
        prefix into whatever budget is left, oldest admission first (FIFO,
        like the bucketed engine prefills admissions in arrival order).  A
        prefill that gets no budget this step simply waits; determinism
        holds because the plan depends only on (running set, n_cached),
        both replayed identically across engines."""
        plan, used = [], 0
        for req in self.batch():
            if req.decoding and used < budget:
                plan.append((req, req.n_cached, 1))
                used += 1
        for req in sorted((r for r in self.running.values() if not r.decoding),
                          key=lambda r: r.admit_seq):
            if used >= budget:
                break
            n = min(len(req.prefix) - req.n_cached, budget - used)
            if n > 0:
                plan.append((req, req.n_cached, n))
                used += n
        return plan

    @property
    def idle(self) -> bool:
        return not self.waiting and not self.running
