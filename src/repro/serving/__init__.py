"""Continuous-batching inference engine for the int4-quantized models.

Layers (bottom-up):

  * ``kv_pages``   -- paged KV-cache: a device-side page pool + per-sequence
                      block tables, a host-side allocator, and a
                      ``ContinuousKVCache`` wrapper so both layouts present
                      one manager interface to the scheduler.
  * ``scheduler``  -- admission queue + continuous batching (requests join
                      and leave at decode-step boundaries) + preemption when
                      the page pool is exhausted.
  * ``engine``     -- drives jit'd prefill/decode steps over the scheduled
                      batch and tracks per-request state and latency stats.
  * ``api``        -- submit()/step()/collect() facade + synthetic Poisson
                      traffic for benchmarking realistic request mixes.
"""

from .api import ServingAPI, poisson_trace, run_trace  # noqa: F401
from .engine import InferenceEngine  # noqa: F401
from .kv_pages import (  # noqa: F401
    ContinuousKVCache,
    PagedKVCacheManager,
    init_paged_caches,
)
from .scheduler import Request, Scheduler  # noqa: F401
