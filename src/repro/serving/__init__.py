"""Continuous-batching inference engine for the int4-quantized models.

Layers (bottom-up):

  * ``kv_pages``   -- paged KV-cache: a device-side page pool + per-sequence
                      block tables, a host-side allocator, and a
                      ``ContinuousKVCache`` wrapper so both layouts present
                      one manager interface to the scheduler.
  * ``scheduler``  -- admission queue + continuous batching (requests join
                      and leave at decode-step boundaries) + preemption when
                      the page pool is exhausted.
  * ``engine``     -- drives jit'd prefill/decode steps over the scheduled
                      batch and tracks per-request state and latency stats.
  * ``api``        -- submit()/step()/collect() facade + synthetic Poisson
                      traffic for benchmarking realistic request mixes.
  * ``chaos``      -- deterministic fault-injection harness: seeded cancel/
                      deadline storms, allocator failures, step exceptions,
                      and mid-run stop/resume, with pool/scheduler
                      invariants asserted after every event and survivor
                      tokens compared bit-for-bit against a fault-free run.

Request lifecycle: every retired request carries exactly one typed
``outcome`` — ``ok | cancelled | timeout | shed | error`` (scheduler
module constants).  All lifecycle bookkeeping is host-side, so the donated
single-signature jits and the zero-steady-state-recompile guarantee are
untouched by cancellation, deadlines, shedding, or snapshots.
"""

from .api import ServingAPI, poisson_trace, run_trace  # noqa: F401
from .chaos import ChaosConfig, chaos_report, run_chaos  # noqa: F401
from .engine import EngineStuckError, InferenceEngine  # noqa: F401
from .kv_pages import (  # noqa: F401
    ContinuousKVCache,
    PagedKVCacheManager,
    init_paged_caches,
)
from .scheduler import (  # noqa: F401
    OUTCOMES,
    Request,
    Scheduler,
    ShedError,
)
