"""Paged KV cache: fixed-size pages from a preallocated pool + block tables.

Device side, each attention layer's cache is a plain dict (scan/vmap-friendly
pytree):

    {"tbl": [B, pages_per_seq] int32,        # logical page -> physical page
     "k":   [num_pages, page_size, KV, hd],  # shared pool
     "v":   [num_pages, page_size, KV, hd],
     (+ "k_scale"/"v_scale" [num_pages, page_size, KV, 1] when quantized)}

The attention module dispatches on the ``"tbl"`` key, so the same model code
consumes the contiguous ring cache and the paged pool.  Logical slot ``j`` of
a sequence lives at flat pool index ``tbl[j // page_size] * page_size +
j % page_size``; a gather along that index vector reconstructs exactly the
[B, max_ctx, KV, hd] layout of the contiguous cache, which is what makes
paged and contiguous decode bit-identical.

Host side, ``PagedKVCacheManager`` owns the free list and per-request page
lists; ``ContinuousKVCache`` wraps the static-slot layout behind the same
manager interface (its "pages" are whole cache rows, so `ensure` only checks
the context bound).
"""

from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, Runtime, ServingConfig
from repro.models.attention import dequantize_kv, quantize_kv


# ------------------------------------------------------- device-side cache --
def init_paged_attn_cache(cfg: ArchConfig, rt: Runtime, batch: int,
                          sv: ServingConfig) -> Dict:
    """One attention layer's paged cache (pool + block table)."""
    kv, hd = cfg.n_kv_heads, cfg.hd
    P, ps = sv.num_pages, sv.page_size
    cache = {"tbl": jnp.zeros((batch, sv.pages_per_seq), jnp.int32)}
    if rt.cache_dtype == "int8":
        z = jnp.zeros((P, ps, kv, hd), jnp.int8)
        s = jnp.zeros((P, ps, kv, 1), jnp.float32)
        cache.update({"k": z, "v": z, "k_scale": s, "v_scale": s})
    elif rt.cache_dtype == "int4":
        z = jnp.zeros((P, ps, kv, hd // 2), jnp.uint8)
        s = jnp.zeros((P, ps, kv, 1), jnp.float32)
        cache.update({"k": z, "v": z, "k_scale": s, "v_scale": s})
    else:
        dt = jnp.bfloat16 if rt.cache_dtype == "bfloat16" else jnp.float32
        z = jnp.zeros((P, ps, kv, hd), dt)
        cache.update({"k": z, "v": z})
    return cache


def init_paged_caches(cfg: ArchConfig, rt: Runtime, batch: int,
                      sv: ServingConfig) -> Dict:
    """Full-model paged caches, mirroring transformer.init_caches' structure
    ({"rep": stacked-over-repeats, "tail": per-layer}).  Paged serving only
    supports pure-attention stacks (SSM/LRU states are O(1) and don't page).
    """
    blocks = tuple(cfg.pattern) + tuple(cfg.tail)
    assert all(bt == "A" for bt in blocks), (
        f"paged KV serving requires an all-attention arch, got {blocks}")

    def unit(_):
        return {f"u{j}": {"attn": init_paged_attn_cache(cfg, rt, batch, sv)}
                for j in range(len(cfg.pattern))}

    stacked = jax.vmap(unit)(jnp.arange(cfg.n_repeats))
    tail = {f"tail{t}": {"attn": init_paged_attn_cache(cfg, rt, batch, sv)}
            for t in range(len(cfg.tail))}
    return {"rep": stacked, "tail": tail}


def paged_write(cache: Dict, k, v, abs_pos) -> Dict:
    """Write k/v [B, n, KV, hd] at absolute positions abs_pos [B, n] through
    the block table.  Negative positions (left-pad / inactive rows) are routed
    to an out-of-bounds page index and dropped.

    One batched 2D scatter per pool leaf — no per-row host loop and no flat
    reshape round-trip, so when the pool rides through a jit with the cache
    argument donated (launch.steps.make_serving_steps) XLA updates the
    donated buffer in place instead of copying it."""
    P, ps = cache["k"].shape[:2]
    tbl = cache["tbl"]
    logical = jnp.clip(abs_pos // ps, 0, tbl.shape[1] - 1)       # [B, n]
    phys = jnp.take_along_axis(tbl, logical, axis=1)
    page = jnp.where(abs_pos >= 0, phys, P)                      # P => dropped
    slot = abs_pos % ps                                          # in [0, ps)

    def write(pool, val):
        return pool.at[page, slot].set(val.astype(pool.dtype), mode="drop")

    out = dict(cache)
    if "k_scale" in cache:
        int4 = cache["k"].dtype == jnp.uint8
        for name, val in (("k", k), ("v", v)):
            q, scale = quantize_kv(val, int4)
            out[name] = write(cache[name], q)
            out[name + "_scale"] = write(cache[name + "_scale"], scale)
    else:
        out["k"] = write(cache["k"], k)
        out["v"] = write(cache["v"], v)
    return out


def paged_read(cache: Dict, last_pos):
    """Gather each row's pages back into the contiguous [B, max_ctx, KV, hd]
    layout.  last_pos [B] is the newest valid absolute position per row (-1 =
    inactive row); returns (k, v, kpos) with kpos[b, j] = j for valid slots,
    -1 otherwise — the same masking contract as the contiguous cache."""
    P, ps = cache["k"].shape[:2]
    tbl = cache["tbl"]
    B, pps = tbl.shape
    max_ctx = pps * ps
    idx = (tbl[:, :, None] * ps
           + jnp.arange(ps, dtype=jnp.int32)[None, None, :]).reshape(B, max_ctx)

    def gather(pool):
        return pool.reshape(P * ps, *pool.shape[2:])[idx]

    if "k_scale" in cache:
        k = dequantize_kv(gather(cache["k"]), gather(cache["k_scale"]))
        v = dequantize_kv(gather(cache["v"]), gather(cache["v_scale"]))
    else:
        k, v = gather(cache["k"]), gather(cache["v"])
    j = jnp.arange(max_ctx, dtype=jnp.int32)[None, :]
    valid = (j <= last_pos[:, None]) & (last_pos[:, None] >= 0)
    return k, v, jnp.where(valid, j, -1)


# -------------------------------------------------- cache-tree manipulation --
def with_block_tables(caches: Dict, tbl) -> Dict:
    """Rebind every layer's block table to `tbl` [B, pages_per_seq] (the same
    positions are cached in every layer, so tables are shared).  Pool leaves
    are passed through untouched; the batch dim of the result follows `tbl`.
    """
    tbl = jnp.asarray(tbl, jnp.int32)

    def walk(node, stacked):
        out = {}
        for key, val in node.items():
            if isinstance(val, dict):
                out[key] = walk(val, stacked)
            elif key == "tbl":
                out[key] = (jnp.broadcast_to(tbl[None],
                                             (val.shape[0],) + tbl.shape)
                            if stacked else tbl)
            else:
                out[key] = val
        return out

    return {"rep": walk(caches["rep"], True),
            "tail": walk(caches["tail"], False)}


def gather_rows(caches: Dict, rows) -> Dict:
    """Slice batch rows out of a contiguous cache tree (rep leaves carry the
    batch at dim 1 under the repeat stacking, tail leaves at dim 0)."""
    r = jnp.asarray(rows, jnp.int32)
    return {"rep": jax.tree.map(lambda l: l[:, r], caches["rep"]),
            "tail": jax.tree.map(lambda l: l[r], caches["tail"])}


def scatter_rows(caches: Dict, sub: Dict, rows) -> Dict:
    """Write a gathered/fresh sub-cache back into the full tree's rows."""
    r = jnp.asarray(rows, jnp.int32)
    return {
        "rep": jax.tree.map(lambda l, s: l.at[:, r].set(s.astype(l.dtype)),
                            caches["rep"], sub["rep"]),
        "tail": jax.tree.map(lambda l, s: l.at[r].set(s.astype(l.dtype)),
                             caches["tail"], sub["tail"]),
    }


# --------------------------------------------------------- host-side managers --
class PagedKVCacheManager:
    """Free-list page allocator + per-request block tables (host side).

    Page ids index the device pool directly.  `ensure(rid, n)` grows rid's
    page list to cover `n` cached tokens and reports whether the pool could
    satisfy it — the scheduler turns a False into a preemption.  Freed pages
    go to the back of the free list so reuse-after-free bugs surface fast.
    """

    def __init__(self, sv: ServingConfig):
        self.sv = sv
        self.free: deque = deque(range(sv.num_pages))
        self.pages: Dict[int, List[int]] = {}
        self.high_water = 0

    # -- capacity ---------------------------------------------------------
    @property
    def available(self) -> int:
        return len(self.free)

    @property
    def in_use(self) -> int:
        return self.sv.num_pages - len(self.free)

    def pages_for(self, n_tokens: int) -> int:
        return max(1, -(-n_tokens // self.sv.page_size))

    def fits_alone(self, n_tokens: int) -> bool:
        """Can a request of this total length run with the whole pool?"""
        return (self.pages_for(n_tokens) <= self.sv.num_pages
                and n_tokens <= self.sv.max_ctx)

    # -- allocation -------------------------------------------------------
    def ensure(self, rid: int, n_tokens: int) -> bool:
        """Grow rid's allocation to cover n_tokens cached slots."""
        if n_tokens > self.sv.max_ctx:
            return False
        have = self.pages.setdefault(rid, [])
        need = self.pages_for(n_tokens) - len(have)
        if need > len(self.free):
            return False
        for _ in range(need):
            have.append(self.free.popleft())
        self.high_water = max(self.high_water, self.in_use)
        return True

    def release(self, rid: int) -> None:
        for p in self.pages.pop(rid, []):
            self.free.append(p)

    def table_row(self, rid: int) -> np.ndarray:
        row = np.zeros((self.sv.pages_per_seq,), np.int32)
        have = self.pages.get(rid, [])
        row[: len(have)] = have
        return row


class ContinuousKVCache:
    """The contiguous (static-slot) layout behind the same manager interface:
    each batch slot owns a full max_ctx cache row, so `ensure` only checks
    the context bound and there is nothing to allocate or preempt."""

    def __init__(self, sv: ServingConfig):
        self.sv = sv
        self.high_water = 0

    @property
    def available(self) -> int:
        return 1 << 30

    def pages_for(self, n_tokens: int) -> int:
        return 0

    def fits_alone(self, n_tokens: int) -> bool:
        return n_tokens <= self.sv.max_ctx

    def ensure(self, rid: int, n_tokens: int) -> bool:
        return n_tokens <= self.sv.max_ctx

    def release(self, rid: int) -> None:
        pass

    def table_row(self, rid: int) -> Optional[np.ndarray]:
        return None
