"""Paged KV cache: fixed-size pages from a preallocated pool + block tables.

Device side, each attention layer's cache is a plain dict (scan/vmap-friendly
pytree):

    {"tbl": [B, pages_per_seq] int32,        # logical page -> physical page
     "k":   [num_pages, page_size, KV, hd],  # shared pool
     "v":   [num_pages, page_size, KV, hd],
     (+ "k_scale"/"v_scale" [num_pages, page_size, KV, 1] when quantized)}

The attention module dispatches on the ``"tbl"`` key, so the same model code
consumes the contiguous ring cache and the paged pool.  Logical slot ``j`` of
a sequence lives at flat pool index ``tbl[j // page_size] * page_size +
j % page_size``; a gather along that index vector reconstructs exactly the
[B, max_ctx, KV, hd] layout of the contiguous cache, which is what makes
paged and contiguous decode bit-identical.

Host side, ``PagedKVCacheManager`` owns the page pool and per-request page
lists; ``ContinuousKVCache`` wraps the static-slot layout behind the same
manager interface (its "pages" are whole cache rows, so `ensure` only checks
the context bound).

Prefix caching (``ServingConfig.prefix_cache``) turns the manager into a
refcounted, content-addressed pool:

  * **Identity.**  Every *full* page is identified by a chained block hash
    (vLLM-style): ``h_i = H(h_{i-1}, tokens[i*ps:(i+1)*ps])``, so a page's
    hash pins the entire token prefix behind it, not just its own tokens.
    Pages are registered in the index the moment they fill (end of prefill
    for prompt pages, decode-step page-boundary crossings for generated
    ones).
  * **Sharing.**  Admission matches the longest indexed page-aligned prefix
    and hands the request those physical pages with ``refcount += 1``; only
    the uncached tail is prefilled.  The hit is capped *below* the full
    prefix so at least one token is always recomputed (its logits seed the
    next token).
  * **Copy-on-write discipline.**  Shared pages are immutable: only full
    pages are ever indexed, hits are page-aligned, and the tail prefill
    starts at the page boundary past the hit — so a writer's positions can
    never land in a page with ``refcount > 1``.  The "partially-filled last
    page" case (a hit that would cover the whole prompt) is resolved by
    capping the hit one page down and re-prefilling that page's tokens into
    a *fresh private page* — copy-on-write implemented as recompute-on-
    write-into-private, which costs at most ``page_size - 1`` tokens and
    needs no device-side page copy.
  * **Eviction.**  ``release`` drops a page's refcount; at zero a registered
    page parks in an LRU of warm pages (still indexed, still hittable —
    this is what makes preempt→resume and repeated system prompts near-
    free) while unregistered pages return to the blank free list.  New
    allocations prefer blank pages and evict the LRU-oldest warm page only
    when the blank list runs dry (``prefix_lru=False`` forgets content at
    release instead).

The device side needs no changes for sharing: block tables simply point
several requests at the same physical pages, and ``paged_write`` routes the
unused table slots' sentinel (page index == num_pages) out of bounds where
writes drop and reads gather zeros.
"""

from __future__ import annotations

from collections import OrderedDict, deque
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, Runtime, ServingConfig
from repro.models.attention import dequantize_kv, quantize_kv
from repro.observability.metrics import NULL_REGISTRY


# ------------------------------------------------------- device-side cache --
def init_paged_attn_cache(cfg: ArchConfig, rt: Runtime, batch: int,
                          sv: ServingConfig) -> Dict:
    """One attention layer's paged cache (pool + block table)."""
    kv, hd = cfg.n_kv_heads, cfg.hd
    P, ps = sv.num_pages, sv.page_size
    cache = {"tbl": jnp.zeros((batch, sv.pages_per_seq), jnp.int32)}
    if rt.cache_dtype == "int8":
        z = jnp.zeros((P, ps, kv, hd), jnp.int8)
        s = jnp.zeros((P, ps, kv, 1), jnp.float32)
        cache.update({"k": z, "v": z, "k_scale": s, "v_scale": s})
    elif rt.cache_dtype == "int4":
        z = jnp.zeros((P, ps, kv, hd // 2), jnp.uint8)
        s = jnp.zeros((P, ps, kv, 1), jnp.float32)
        cache.update({"k": z, "v": z, "k_scale": s, "v_scale": s})
    else:
        dt = jnp.bfloat16 if rt.cache_dtype == "bfloat16" else jnp.float32
        z = jnp.zeros((P, ps, kv, hd), dt)
        cache.update({"k": z, "v": z})
    return cache


def init_paged_caches(cfg: ArchConfig, rt: Runtime, batch: int,
                      sv: ServingConfig) -> Dict:
    """Full-model paged caches, mirroring transformer.init_caches' structure
    ({"rep": stacked-over-repeats, "tail": per-layer}).  Paged serving only
    supports pure-attention stacks (SSM/LRU states are O(1) and don't page).
    """
    blocks = tuple(cfg.pattern) + tuple(cfg.tail)
    assert all(bt == "A" for bt in blocks), (
        f"paged KV serving requires an all-attention arch, got {blocks}")

    def unit(_):
        return {f"u{j}": {"attn": init_paged_attn_cache(cfg, rt, batch, sv)}
                for j in range(len(cfg.pattern))}

    stacked = jax.vmap(unit)(jnp.arange(cfg.n_repeats))
    tail = {f"tail{t}": {"attn": init_paged_attn_cache(cfg, rt, batch, sv)}
            for t in range(len(cfg.tail))}
    return {"rep": stacked, "tail": tail}


def paged_write(cache: Dict, k, v, abs_pos) -> Dict:
    """Write k/v [B, n, KV, hd] at absolute positions abs_pos [B, n] through
    the block table.  Negative positions (left-pad / inactive rows) are routed
    to an out-of-bounds page index and dropped.

    One batched 2D scatter per pool leaf — no per-row host loop and no flat
    reshape round-trip, so when the pool rides through a jit with the cache
    argument donated (launch.steps.make_serving_steps) XLA updates the
    donated buffer in place instead of copying it."""
    P, ps = cache["k"].shape[:2]
    tbl = cache["tbl"]
    logical = jnp.clip(abs_pos // ps, 0, tbl.shape[1] - 1)       # [B, n]
    phys = jnp.take_along_axis(tbl, logical, axis=1)
    page = jnp.where(abs_pos >= 0, phys, P)                      # P => dropped
    slot = abs_pos % ps                                          # in [0, ps)

    def write(pool, val):
        return pool.at[page, slot].set(val.astype(pool.dtype), mode="drop")

    out = dict(cache)
    if "k_scale" in cache:
        int4 = cache["k"].dtype == jnp.uint8
        for name, val in (("k", k), ("v", v)):
            q, scale = quantize_kv(val, int4)
            out[name] = write(cache[name], q)
            out[name + "_scale"] = write(cache[name + "_scale"], scale)
    else:
        out["k"] = write(cache["k"], k)
        out["v"] = write(cache["v"], v)
    return out


def ragged_paged_write(cache: Dict, k, v, abs_pos) -> Dict:
    """Token-major twin of ``paged_write``: k/v [1, T, KV, hd] packed rows,
    each routed through the table row its token belongs to
    (``cache["slots"]`` [T], bound by ``with_token_slots``) at absolute
    position ``abs_pos`` [1, T].  Padding rows (slot or position -1) go to
    the out-of-bounds page and are dropped.  Quantization is per token —
    the identical ``quantize_kv`` math to the bucketed writes, so a pool
    filled by chunked ragged steps is bit-identical to one filled by
    bucketed prefill + decode."""
    P, ps = cache["k"].shape[:2]
    tbl, slots = cache["tbl"], cache["slots"]       # [max_batch, pps], [T]
    pos = abs_pos.reshape(-1)                       # [T]
    logical = jnp.clip(pos // ps, 0, tbl.shape[1] - 1)
    phys = tbl[jnp.clip(slots, 0, tbl.shape[0] - 1), logical]
    page = jnp.where((pos >= 0) & (slots >= 0), phys, P)   # P => dropped
    slot_in_page = pos % ps

    def write(pool, val):
        return pool.at[page, slot_in_page].set(val.astype(pool.dtype),
                                               mode="drop")

    out = dict(cache)
    if "k_scale" in cache:
        int4 = cache["k"].dtype == jnp.uint8
        for name, val in (("k", k), ("v", v)):
            q, scale = quantize_kv(val, int4)
            out[name] = write(cache[name], q[0])
            out[name + "_scale"] = write(cache[name + "_scale"], scale[0])
    else:
        out["k"] = write(cache["k"], k[0])
        out["v"] = write(cache["v"], v[0])
    return out


def paged_read(cache: Dict, last_pos):
    """Gather each row's pages back into the contiguous [B, max_ctx, KV, hd]
    layout.  last_pos [B] is the newest valid absolute position per row (-1 =
    inactive row); returns (k, v, kpos) with kpos[b, j] = j for valid slots,
    -1 otherwise — the same masking contract as the contiguous cache.

    Table slots holding the out-of-bounds sentinel (page index == num_pages,
    the unallocated-slot marker `table_row` writes) gather exact zeros via
    fill-mode indexing — stale pool data behind a dead table entry can never
    leak into the dense layout (attention masks those slots, but a NaN in a
    recycled page would still poison `0 * NaN` in the PV contraction)."""
    P, ps = cache["k"].shape[:2]
    tbl = cache["tbl"]
    B, pps = tbl.shape
    max_ctx = pps * ps
    idx = (tbl[:, :, None] * ps
           + jnp.arange(ps, dtype=jnp.int32)[None, None, :]).reshape(B, max_ctx)

    def gather(pool):
        flat = pool.reshape(P * ps, *pool.shape[2:])
        return flat.at[idx].get(mode="fill", fill_value=0)

    if "k_scale" in cache:
        k = dequantize_kv(gather(cache["k"]), gather(cache["k_scale"]))
        v = dequantize_kv(gather(cache["v"]), gather(cache["v_scale"]))
    else:
        k, v = gather(cache["k"]), gather(cache["v"])
    j = jnp.arange(max_ctx, dtype=jnp.int32)[None, :]
    valid = (j <= last_pos[:, None]) & (last_pos[:, None] >= 0)
    return k, v, jnp.where(valid, j, -1)


# -------------------------------------------------- cache-tree manipulation --
def with_block_tables(caches: Dict, tbl) -> Dict:
    """Rebind every layer's block table to `tbl` [B, pages_per_seq] (the same
    positions are cached in every layer, so tables are shared).  Pool leaves
    are passed through untouched; the batch dim of the result follows `tbl`.
    """
    tbl = jnp.asarray(tbl, jnp.int32)

    def walk(node, stacked):
        out = {}
        for key, val in node.items():
            if isinstance(val, dict):
                out[key] = walk(val, stacked)
            elif key == "tbl":
                out[key] = (jnp.broadcast_to(tbl[None],
                                             (val.shape[0],) + tbl.shape)
                            if stacked else tbl)
            else:
                out[key] = val
        return out

    return {"rep": walk(caches["rep"], True),
            "tail": walk(caches["tail"], False)}


def with_token_slots(caches: Dict, tbl, slots) -> Dict:
    """Bind the ragged step's routing scalars into every attention cache:
    the *whole* block-table matrix `tbl` [max_batch, pages_per_seq] plus a
    per-token table-row vector `slots` [T] (-1 = padding row).  Presence of
    the "slots" leaf is what switches ``models.attention.apply_attention``
    onto the ragged token-major path."""
    tbl = jnp.asarray(tbl, jnp.int32)
    slots = jnp.asarray(slots, jnp.int32)

    def walk(node, stacked):
        out = {}
        for key, val in node.items():
            if isinstance(val, dict):
                out[key] = walk(val, stacked)
            elif key == "tbl":
                reps = (val.shape[0],) if stacked else ()
                out[key] = jnp.broadcast_to(tbl[None] if stacked else tbl,
                                            reps + tbl.shape)
                out["slots"] = jnp.broadcast_to(
                    slots[None] if stacked else slots, reps + slots.shape)
            elif key == "slots":
                continue                            # rebound alongside tbl
            else:
                out[key] = val
        return out

    return {"rep": walk(caches["rep"], True),
            "tail": walk(caches["tail"], False)}


def gather_rows(caches: Dict, rows) -> Dict:
    """Slice batch rows out of a contiguous cache tree (rep leaves carry the
    batch at dim 1 under the repeat stacking, tail leaves at dim 0)."""
    r = jnp.asarray(rows, jnp.int32)
    return {"rep": jax.tree.map(lambda l: l[:, r], caches["rep"]),
            "tail": jax.tree.map(lambda l: l[r], caches["tail"])}


def scatter_rows(caches: Dict, sub: Dict, rows) -> Dict:
    """Write a gathered/fresh sub-cache back into the full tree's rows."""
    r = jnp.asarray(rows, jnp.int32)
    return {
        "rep": jax.tree.map(lambda l, s: l.at[:, r].set(s.astype(l.dtype)),
                            caches["rep"], sub["rep"]),
        "tail": jax.tree.map(lambda l, s: l.at[r].set(s.astype(l.dtype)),
                             caches["tail"], sub["tail"]),
    }


# --------------------------------------------------------- host-side managers --
_HASH_SEED = 0x9E3779B97F4A7C15


def _chain_hash(prev: int, tokens: np.ndarray) -> int:
    """Chained block hash: pins the whole prefix behind a page, not just
    the page's own tokens."""
    return hash((prev, np.asarray(tokens, np.int32).tobytes()))


class PagedKVCacheManager:
    """Refcounted, content-addressed page pool + per-request block tables.

    Page ids index the device pool directly.  `ensure(rid, n)` grows rid's
    page list to cover `n` cached tokens and reports whether the pool could
    satisfy it — the scheduler turns a False into a preemption.  With
    ``sv.prefix_cache`` on, `admit_request` shares already-filled pages
    (see the module docstring for the sharing/COW/eviction design); every
    page is always in exactly one of three states:

      blank    -- on `self.blank`, contents meaningless, refcount 0
      warm     -- refcount 0 but still registered in the prefix index
                  (`self.warm`, LRU order); allocatable after blanks run dry
      in use   -- refcount >= 1, owned by that many requests

    which is the invariant the allocator property test asserts.
    """

    def __init__(self, sv: ServingConfig, metrics=None):
        self.sv = sv
        # telemetry registry (observability.metrics); the manager bumps
        # event counters at its natural seams, the engine samples occupancy
        # gauges at step boundaries
        self.metrics = metrics if metrics is not None else NULL_REGISTRY
        self.blank: deque = deque(range(sv.num_pages))
        self.warm: "OrderedDict[int, None]" = OrderedDict()  # refcount-0, indexed
        self.pages: Dict[int, List[int]] = {}
        self.refcount: Dict[int, int] = {}
        self.index: Dict[int, int] = {}        # chain hash -> page
        self.page_hash: Dict[int, int] = {}    # page -> chain hash
        self._chain: Dict[int, Tuple[int, int]] = {}  # rid -> (pages hashed, h)
        self.high_water = 0
        # prefix-cache counters (engine stats surface these)
        self.n_lookups = 0
        self.n_hit_tokens = 0
        self.n_evictions = 0
        # chaos hook (serving/chaos.py): the next N admissions that would
        # allocate pages report capacity failure instead — exercising the
        # all-or-nothing admission path without real pool pressure.  Host-
        # side only; never touches device state.
        self.fail_next_admits = 0

    # -- capacity ---------------------------------------------------------
    @property
    def free(self) -> List[int]:
        """Allocatable pages, blank first then warm in eviction order (kept
        as a property for callers/tests that inspect the free pool)."""
        return list(self.blank) + list(self.warm)

    @property
    def available(self) -> int:
        return len(self.blank) + len(self.warm)

    @property
    def in_use(self) -> int:
        return self.sv.num_pages - self.available

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.sv.page_size)

    def fits_alone(self, n_tokens: int) -> bool:
        """Can a request of this total length run with the whole pool?"""
        return (self.pages_for(n_tokens) <= self.sv.num_pages
                and n_tokens <= self.sv.max_ctx)

    def capacity_desc(self) -> str:
        return (f"max_ctx={self.sv.max_ctx}, "
                f"pool={self.sv.num_pages} pages "
                f"of {self.sv.page_size} tokens")

    # -- allocation -------------------------------------------------------
    def _alloc_page(self) -> Optional[int]:
        """One blank-or-evicted page with no index entry left behind."""
        if self.blank:
            return self.blank.popleft()
        if self.warm:
            page, _ = self.warm.popitem(last=False)      # LRU-oldest
            h = self.page_hash.pop(page)
            del self.index[h]
            self.n_evictions += 1
            self.metrics.counter("prefix_evictions_total",
                                 "warm pages evicted to blank").inc()
            return page
        return None

    def ensure(self, rid: int, n_tokens: int) -> bool:
        """Grow rid's allocation to cover n_tokens cached slots.  New pages
        are private (refcount 1); shared pages arrive via admit_request."""
        if n_tokens > self.sv.max_ctx:
            return False
        have = self.pages.setdefault(rid, [])
        need = self.pages_for(n_tokens) - len(have)
        if need > self.available:
            return False                                  # all-or-nothing
        for _ in range(need):
            page = self._alloc_page()
            self.refcount[page] = 1
            have.append(page)
        self.high_water = max(self.high_water, self.in_use)
        return True

    def release(self, rid: int) -> None:
        """Drop rid's hold on its pages.  Registered pages whose refcount
        hits zero stay warm (indexed, LRU-evictable); unregistered ones
        go blank immediately, as does everything when prefix_lru is off."""
        for p in self.pages.pop(rid, []):
            self.refcount[p] -= 1
            if self.refcount[p]:
                continue
            del self.refcount[p]
            if p in self.page_hash and self.sv.prefix_lru:
                self.warm[p] = None                       # most-recently freed
                self.warm.move_to_end(p)
            else:
                h = self.page_hash.pop(p, None)
                if h is not None:
                    del self.index[h]
                self.blank.append(p)
        self._chain.pop(rid, None)

    # -- prefix cache ------------------------------------------------------
    def _match(self, tokens: np.ndarray) -> Tuple[List[int], int]:
        """Pure longest-indexed-prefix walk: (matched pages, chain hash at
        the match point).  Capped strictly below len(tokens) so a caller
        always recomputes at least the final token (whose logits produce
        the next token) — and therefore never writes a shared page: the
        capped page is re-prefilled into a fresh private one instead
        (recompute-style copy-on-write)."""
        ps = self.sv.page_size
        max_full = max(len(tokens) - 1, 0) // ps
        h = _HASH_SEED
        shared: List[int] = []
        for i in range(max_full):
            h_next = _chain_hash(h, tokens[i * ps:(i + 1) * ps])
            page = self.index.get(h_next)
            if page is None:
                break
            shared.append(page)
            h = h_next
        return shared, h

    def admit_request(self, rid: int, tokens: np.ndarray,
                      n_tokens: int) -> Optional[int]:
        """Admission-time allocation, all-or-nothing: match the prefix
        cache, take shared holds (refcount++) on the matched pages, and
        allocate private pages for the remainder of `n_tokens` slots.
        Returns the hit length in tokens, or None when the request doesn't
        fit — in which case *nothing* changed: no refcounts, no LRU
        touches, no hit counters (a queue head blocked on capacity retries
        every step and must not inflate stats or churn eviction order)."""
        assert rid not in self.pages, f"rid {rid} already holds pages"
        if n_tokens > self.sv.max_ctx:
            return None
        if self.fail_next_admits:
            # injected allocator failure: behave exactly like a capacity
            # miss — nothing held, nothing counted, the request waits
            self.fail_next_admits -= 1
            self.metrics.counter(
                "chaos_alloc_failures_total",
                "admissions failed by the chaos allocator hook").inc()
            return None
        shared, h = self._match(tokens) if self.sv.prefix_cache \
            else ([], _HASH_SEED)
        # shared pages currently warm stop being allocatable once held
        warm_shared = sum(1 for p in shared if not self.refcount.get(p))
        need = self.pages_for(n_tokens) - len(shared)
        if need > self.available - warm_shared:
            return None
        for p in shared:
            if not self.refcount.get(p):
                del self.warm[p]                          # warm -> in use
            self.refcount[p] = self.refcount.get(p, 0) + 1
        have = self.pages[rid] = list(shared)
        for _ in range(max(need, 0)):
            page = self._alloc_page()
            self.refcount[page] = 1
            have.append(page)
        self._chain[rid] = (len(shared), h)
        self.high_water = max(self.high_water, self.in_use)
        if self.sv.prefix_cache:
            self.n_lookups += 1
            self.n_hit_tokens += len(shared) * self.sv.page_size
            self.metrics.counter("prefix_lookups_total",
                                 "admission prefix-cache lookups").inc()
            if shared:
                self.metrics.counter("prefix_hits_total",
                                     "admissions that matched >=1 page").inc()
                self.metrics.counter("prefix_hit_pages_total",
                                     "pages served from the cache").inc(
                                         len(shared))
        return len(shared) * self.sv.page_size

    def register_upto(self, rid: int, tokens: np.ndarray, n_valid: int) -> None:
        """Index every full page of rid's prefix whose contents are written
        (tokens[:n_valid] are cached device-side).  Idempotent and
        incremental: the per-rid chain state resumes where the last call
        stopped.  First-writer-wins — if another page already owns a hash,
        ours stays private (duplicate content, freed back to blank later)."""
        if not self.sv.prefix_cache:
            return
        ps = self.sv.page_size
        have = self.pages.get(rid, [])
        done, h = self._chain.get(rid, (0, _HASH_SEED))
        full = min(n_valid // ps, len(have))
        for i in range(done, full):
            h = _chain_hash(h, tokens[i * ps:(i + 1) * ps])
            page = have[i]
            if h not in self.index and page not in self.page_hash:
                self.index[h] = page
                self.page_hash[page] = h
        self._chain[rid] = (full, h)

    # -- block tables ------------------------------------------------------
    def table_row(self, rid: int) -> np.ndarray:
        """Unallocated logical slots carry the out-of-bounds sentinel
        (== num_pages): `paged_write` drops writes through it and
        `paged_read` gathers zeros — a dead slot can never alias physical
        page 0 and silently resurface another request's data."""
        row = np.full((self.sv.pages_per_seq,), self.sv.num_pages, np.int32)
        have = self.pages.get(rid, [])
        row[: len(have)] = have
        return row

    # -- invariants --------------------------------------------------------
    def check_invariants(self) -> None:
        """Allocator invariants, assertable after any event.  This is the
        single checker shared by the hypothesis allocator property test and
        the chaos harness:

          * blank / warm / in-use partition the pool exactly once
          * refcounts are >= 1 and equal the per-request ownership multiset
          * ``available`` + sum of 1/refcount ownership shares == pool size
          * no request holds the same page twice
          * only registered (sealed, immutable) pages are ever shared
          * warm pages are exactly the registered refcount-0 pages
          * index and page_hash are inverse maps
        """
        blank, warm = set(self.blank), set(self.warm)
        in_use = set(self.refcount)
        assert len(blank) == len(self.blank), "blank list holds duplicates"
        assert not (blank & warm) and not (blank & in_use) \
            and not (warm & in_use), "pool state overlap"
        assert blank | warm | in_use == set(range(self.sv.num_pages)), \
            "pool partition incomplete"
        assert all(c >= 1 for c in self.refcount.values())
        shares = sum(1.0 / self.refcount[p]
                     for pages in self.pages.values() for p in pages)
        assert abs(self.available + shares - self.sv.num_pages) < 1e-9, \
            "ownership shares + free pages != pool"
        owners: Dict[int, int] = {}
        for rid, pages in self.pages.items():
            assert len(set(pages)) == len(pages), \
                f"rid {rid} holds a page twice"
            for p in pages:
                owners[p] = owners.get(p, 0) + 1
        assert owners == self.refcount, "refcounts disagree with ownership"
        for p, c in self.refcount.items():
            if c > 1:
                assert p in self.page_hash, f"unsealed page {p} shared"
        assert all(p in self.page_hash for p in warm), \
            "warm page lost its registration"
        assert self.index == {h: p for p, h in self.page_hash.items()}, \
            "index/page_hash out of sync"

    # -- snapshot ----------------------------------------------------------
    def state(self) -> Dict:
        """Host-side allocator state for engine.snapshot(): everything
        needed to resume page accounting exactly.  Note the prefix-index
        keys are Python hashes — stable within a process (the chaos
        stop/resume path), but a snapshot restored in a *different* process
        needs PYTHONHASHSEED pinned for warm-page hits to survive; shared
        in-use page structure restores correctly regardless."""
        return {
            "blank": list(self.blank),
            "warm": list(self.warm),
            "pages": {rid: list(p) for rid, p in self.pages.items()},
            "refcount": dict(self.refcount),
            "index": dict(self.index),
            "page_hash": dict(self.page_hash),
            "chain": dict(self._chain),
            "high_water": self.high_water,
            "n_lookups": self.n_lookups,
            "n_hit_tokens": self.n_hit_tokens,
            "n_evictions": self.n_evictions,
        }

    def load_state(self, st: Dict) -> None:
        self.blank = deque(st["blank"])
        self.warm = OrderedDict((p, None) for p in st["warm"])
        self.pages = {rid: list(p) for rid, p in st["pages"].items()}
        self.refcount = dict(st["refcount"])
        self.index = dict(st["index"])
        self.page_hash = dict(st["page_hash"])
        self._chain = dict(st["chain"])
        self.high_water = st["high_water"]
        self.n_lookups = st["n_lookups"]
        self.n_hit_tokens = st["n_hit_tokens"]
        self.n_evictions = st["n_evictions"]
        self.check_invariants()


class ContinuousKVCache:
    """The contiguous (static-slot) layout behind the same manager interface:
    each batch slot owns a full max_ctx cache row, so `ensure` only checks
    the context bound and there is nothing to allocate, share, or preempt."""

    def __init__(self, sv: ServingConfig, metrics=None):
        self.sv = sv
        self.metrics = metrics if metrics is not None else NULL_REGISTRY
        self.high_water = 0
        self.n_lookups = 0
        self.n_hit_tokens = 0
        self.n_evictions = 0

    @property
    def available(self) -> int:
        return 1 << 30

    def pages_for(self, n_tokens: int) -> int:
        return 0

    def fits_alone(self, n_tokens: int) -> bool:
        return n_tokens <= self.sv.max_ctx

    def capacity_desc(self) -> str:
        return f"max_ctx={self.sv.max_ctx}"

    def ensure(self, rid: int, n_tokens: int) -> bool:
        return n_tokens <= self.sv.max_ctx

    def release(self, rid: int) -> None:
        pass

    def admit_request(self, rid: int, tokens, n_tokens: int) -> Optional[int]:
        return 0 if n_tokens <= self.sv.max_ctx else None

    def register_upto(self, rid: int, tokens, n_valid: int) -> None:
        pass

    def table_row(self, rid: int) -> Optional[np.ndarray]:
        return None

    def check_invariants(self) -> None:
        pass                       # nothing allocated, nothing to violate

    def state(self) -> Dict:
        return {}

    def load_state(self, st: Dict) -> None:
        pass
