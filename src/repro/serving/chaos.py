"""Deterministic fault-injection harness for the serving engine.

The harness replays one seeded synthetic trace through an engine while a
seeded event stream injects every lifecycle hazard the stack claims to
survive:

  * **cancel storms**   -- random live requests (queued, prefilling, or
                           mid-decode) cancelled at step boundaries
  * **deadline storms** -- a fraction of requests carry tight TTLs and are
                           retired by the step-boundary sweep
  * **allocator failures** -- ``PagedKVCacheManager.fail_next_admits``
                           makes admissions report capacity failure,
                           exercising the all-or-nothing admission path
  * **step exceptions** -- ``engine.inject_step_fault`` raises at the top
                           of a step; the harness drives steps through
                           ``distributed.fault_tolerance.run_with_retries``
  * **stop/resume**     -- ``engine.snapshot()`` +
                           ``InferenceEngine.restore()`` mid-run; the
                           restored engine continues the same trace

Everything is derived from ``ChaosConfig.seed`` through
``np.random.default_rng`` and a fake step-index clock, so a failing seed
replays exactly.  After *every* event the harness asserts the scheduler and
page-pool structural invariants (``check_invariants``), and after the run
drains it asserts zero leaked pages and — the strong claim — that every
surviving request (outcome ``ok``) emitted tokens *bit-identical* to a
fault-free reference run of the same trace.  Greedy decode over a bf16 KV
cache is lossless under recompute-resume and prefix sharing, so cancels,
timeouts, preemptions, and restores around a request must not perturb it.

Token identity across the bucketed and ragged step modes additionally
requires ``Runtime(attn_impl="chunked")`` (flash's online softmax rounds
differently); ``launch/serve.py --scenario chaos`` sets that up.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ArchConfig, Runtime, ServingConfig
from repro.distributed.fault_tolerance import run_with_retries
from repro.serving.engine import InferenceEngine, build_params
from repro.serving.scheduler import OK, ShedError


class InjectedFault(RuntimeError):
    """The fault `inject_step_fault` plants — typed so tests can tell an
    injected failure from a real one escaping the retry wrapper."""


class _StepClock:
    """Fake engine clock: t == current step index.  Deadlines, TTFT, and
    the expiry sweep all read this, so a chaos run's timing is a pure
    function of the seed — no wall-clock nondeterminism."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Knobs for one seeded chaos run.  Probabilities are per step; every
    draw comes from one ``default_rng([seed, 1])`` stream (the trace uses
    ``[seed, 0]``), so two runs with the same config are identical."""

    seed: int = 0
    n_requests: int = 12
    rate_per_step: float = 1.0
    prompt_lens: Tuple[int, ...] = (6, 12, 20)
    gen_lens: Tuple[int, ...] = (4, 8)
    p_cancel: float = 0.10           # chance of a cancel event this step
    n_cancel: int = 2                # live rids cancelled per event
    p_deadline: float = 0.25         # chance a request carries a TTL
    deadline_range: Tuple[float, float] = (4.0, 40.0)   # steps (fake clock)
    p_alloc_fail: float = 0.08       # arm one injected admission failure
    p_step_fault: float = 0.08       # plant one step exception (retried)
    stop_resume_at: Tuple[int, ...] = ()   # snapshot/restore at these steps
    max_steps: int = 2000


def _make_trace(chaos: ChaosConfig, vocab: int) -> List[Tuple]:
    """(arrival_step, prompt, max_new) triples, drawn from the trace
    stream — shared verbatim by the reference and every chaos run."""
    rng = np.random.default_rng([chaos.seed, 0])
    t, out = 0.0, []
    for _ in range(chaos.n_requests):
        t += rng.exponential(1.0 / max(chaos.rate_per_step, 1e-9))
        L = int(rng.choice(list(chaos.prompt_lens)))
        out.append((int(t),
                    rng.integers(0, vocab, size=L, dtype=np.int32),
                    int(rng.choice(list(chaos.gen_lens)))))
    return out


def reference_tokens(cfg: ArchConfig, rt: Runtime, sv: ServingConfig,
                     trace: List[Tuple], params=None) -> Dict[int, List[int]]:
    """Fault-free run of the trace: no deadlines, no shedding (max_queue
    lifted), no injected failures.  Returns {rid: generated tokens} — the
    bit-identity oracle every chaos survivor is compared against."""
    sv = dataclasses.replace(sv, max_queue=0)
    clock = _StepClock()
    eng = InferenceEngine(cfg, rt, sv, params=params, clock=clock)
    eng.warmup(prompt_lens=[len(p) for _, p, _ in trace])
    out: Dict[int, List[int]] = {}
    i, step_idx = 0, 0
    while i < len(trace) or not eng.scheduler.idle:
        assert step_idx < 100_000, "reference run did not drain"
        clock.t = float(step_idx)
        while i < len(trace) and trace[i][0] <= step_idx:
            eng.submit(trace[i][1], trace[i][2])
            i += 1
        eng.step()
        for r in eng.collect():
            out[r.rid] = list(r.tokens)
        step_idx += 1
    return out


def run_chaos(cfg: ArchConfig, rt: Runtime, sv: ServingConfig,
              chaos: ChaosConfig, params=None,
              reference: Optional[Dict[int, List[int]]] = None) -> Dict:
    """One seeded chaos run.  Asserts scheduler + pool invariants after
    every step and restore, a fully drained engine (no leaked pages, every
    submitted request retired with a typed outcome), and survivor
    token-identity against `reference` (computed here if not given).
    Returns a JSON-able report; assertion failures ARE the test failing."""
    if params is None:
        params = build_params(cfg, rt)
    trace = _make_trace(chaos, cfg.vocab)
    if reference is None:
        reference = reference_tokens(cfg, rt, sv, trace, params=params)

    clock = _StepClock()
    eng = InferenceEngine(cfg, rt, sv, params=params, clock=clock)
    eng.warmup(prompt_lens=[len(p) for _, p, _ in trace])
    rng = np.random.default_rng([chaos.seed, 1])
    stop_at = set(chaos.stop_resume_at)
    events = {"cancels": 0, "sheds": 0, "alloc_fails": 0,
              "step_faults": 0, "stop_resumes": 0, "deadlines": 0}
    finished: Dict[int, object] = {}

    def check(engine):
        engine.scheduler.check_invariants()
        engine.kv.check_invariants()

    i, step_idx = 0, 0
    while i < len(trace) or not eng.scheduler.idle:
        assert step_idx < chaos.max_steps, \
            f"chaos run (seed {chaos.seed}) not drained " \
            f"after {chaos.max_steps} steps"
        clock.t = float(step_idx)
        while i < len(trace) and trace[i][0] <= step_idx:
            ttl = None
            if rng.random() < chaos.p_deadline:
                ttl = float(rng.uniform(*chaos.deadline_range))
                events["deadlines"] += 1
            try:
                eng.submit(trace[i][1], trace[i][2], deadline_s=ttl)
            except ShedError:
                events["sheds"] += 1      # still retires through collect()
            i += 1
        if rng.random() < chaos.p_cancel:
            live = sorted(rid for rid, r in eng._all.items()
                          if r.t_finish is None)
            for j in rng.permutation(len(live))[:chaos.n_cancel]:
                if eng.cancel(live[int(j)]):
                    events["cancels"] += 1
                check(eng)
        if rng.random() < chaos.p_alloc_fail \
                and hasattr(eng.kv, "fail_next_admits"):
            eng.kv.fail_next_admits += 1
            events["alloc_fails"] += 1
        if rng.random() < chaos.p_step_fault:
            eng.inject_step_fault(
                InjectedFault(f"injected at step {step_idx}"))
            events["step_faults"] += 1
        run_with_retries(eng.step, max_retries=2)
        for r in eng.collect():
            finished[r.rid] = r
        check(eng)
        if step_idx in stop_at:
            snap = eng.snapshot()
            eng = InferenceEngine.restore(snap, params=params, clock=clock)
            events["stop_resumes"] += 1
            check(eng)
        step_idx += 1

    # -- drain assertions --------------------------------------------------
    check(eng)
    leaked = getattr(eng.kv, "in_use", 0)
    assert leaked == 0, f"{leaked} pages leaked after drain"
    assert sorted(finished) == list(range(chaos.n_requests)), \
        f"requests lost: retired {sorted(finished)}"
    outcomes: Dict[str, int] = {}
    for r in finished.values():
        assert r.outcome is not None, f"rid {r.rid} retired without outcome"
        outcomes[r.outcome] = outcomes.get(r.outcome, 0) + 1

    # -- survivor token identity ------------------------------------------
    survivors = {rid: r for rid, r in finished.items() if r.outcome == OK}
    mismatched = [rid for rid, r in survivors.items()
                  if list(r.tokens) != reference[rid]]
    assert not mismatched, \
        f"seed {chaos.seed}: survivors {mismatched} diverged from the " \
        f"fault-free reference"
    return {
        "seed": chaos.seed,
        "step_mode": sv.step,
        "steps": step_idx,
        "events": events,
        "outcomes": outcomes,
        "survivors": len(survivors),
        "survivors_identical": True,
        "leaked_pages": leaked,
        "preemptions": eng.scheduler.n_preemptions,
        "recompiles_steady_state": eng.tm.jit_watch.steady_state,
        "pool_high_water": getattr(eng.kv, "high_water", 0),
    }


#: cancel-heavy preset: every hazard off except a high-rate cancel storm —
#: the scenario that stresses refcount bookkeeping hardest (shared prefix
#: pages must stay warm while their siblings die mid-decode)
CANCEL_STORM = ChaosConfig(p_cancel=0.5, n_cancel=3, p_deadline=0.0,
                           p_alloc_fail=0.0, p_step_fault=0.0)


def chaos_report(cfg: ArchConfig, rt: Runtime, sv: ServingConfig,
                 chaos: ChaosConfig, modes: Tuple[str, ...] =
                 ("bucketed", "ragged"), params=None) -> Dict:
    """Run the same seeded chaos scenario in every requested step mode
    against ONE fault-free bucketed reference (cross-mode identity needs
    ``rt.attn_impl == "chunked"``).  Aggregates the per-run reports under
    top-level pass/fail fields CI can assert on directly."""
    if params is None:
        params = build_params(cfg, rt)
    trace = _make_trace(chaos, cfg.vocab)
    ref = reference_tokens(cfg, rt,
                           dataclasses.replace(sv, step="bucketed"),
                           trace, params=params)
    runs = [run_chaos(cfg, rt, dataclasses.replace(sv, step=mode),
                      chaos, params=params, reference=ref)
            for mode in modes]
    return {
        "seed": chaos.seed,
        "survivors_identical": all(r["survivors_identical"] for r in runs),
        "recompiles_steady_state": max(r["recompiles_steady_state"]
                                       for r in runs),
        "leaked_pages": max(r["leaked_pages"] for r in runs),
        "runs": runs,
    }
