"""Inference engine: drives jit'd prefill/decode steps over the scheduled
batch with per-request state tracking and latency/throughput stats.

One `step()` is a decode-step boundary: admit (+prefill) newly-arrived
requests, preempt if the page pool is dry, run one decode step for the
running set, retire finished requests.  Greedy decoding (argmax), which is
what the bit-exactness harness compares across KV layouts.

Batch construction is identical for both layouts — running requests compacted
in slot order, padded to the nearest bucket with inactive rows (position -1:
attention masks them and their cache writes are dropped) — so paged and
contiguous runs of the same trace execute the same program shapes and the
same per-row math.  The layouts differ only in where KV bytes live:

  * paged      -- pool + block tables live on device; the whole decode step
                  is one jit (table gather + forward + fused paged attention
                  + greedy argmax) with the cache pool donated through it.
                  Block-table rows move host->device only when a request is
                  admitted or its page allocation grows — never per step.
  * contiguous -- each slot owns a max_ctx row; admission scatters a freshly
                  prefilled row into the full cache (an O(cache) copy that the
                  paged layout exists to avoid — see EXPERIMENTS.md §Serving).

`profile()` attributes one decode step's cost: the attention op is timed
standalone (the same kernels.ops dispatch the model executes) against the
full step time, so perf PRs can tell attention regressions from GEMM ones.
The result is stamped with the step counter at capture time
(``profile_at_step`` in ``stats()``), so a report can't silently pair a
warmup-window profile with end-of-run stats.

Telemetry (``repro.observability``): every engine owns a `Telemetry`
bundle — a metrics registry fed at the natural seams (TTFT/ITL histograms
at retire time, queue/pool gauges at step boundaries, token counters at
prefill/decode), a trace recorder that renders the run as a Perfetto
timeline (one lane per batch slot: request residency segments, admission
prefills, preemption ends), and a jit recompile sentinel polled after
every prefill/decode call.  All of it is host-side bookkeeping off the
traced path: telemetry on vs off is token-identical by construction.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, Runtime, ServingConfig
from repro.core.quant_plan import pack_for_serving
from repro.distributed.fault_tolerance import StepDeadlineExceeded, Watchdog
from repro.kernels import autotune
from repro.launch.steps import make_ragged_step, make_serving_steps
from repro.observability import COUNT_BUCKETS, Telemetry
from repro.models import init_caches, init_model
from repro.serving.kv_pages import (
    ContinuousKVCache,
    PagedKVCacheManager,
    gather_rows,
    init_paged_caches,
    scatter_rows,
    with_block_tables,
    with_token_slots,
)
from repro.serving.scheduler import (
    CANCELLED,
    ERROR,
    OK,
    SHED,
    TIMEOUT,
    Request,
    Scheduler,
    ShedError,
)


class EngineStuckError(RuntimeError):
    """run_until_idle() exhausted its step budget with work still queued or
    running — a wedged engine must be loud, not a silent return.  Carries
    the stuck state so an operator (or the chaos harness) can see *what*
    is wedged without re-running under a debugger."""

    def __init__(self, max_steps: int, queued, running,
                 pool_in_use: int, pool_pages: int):
        self.max_steps = max_steps
        self.queued = list(queued)
        self.running = list(running)
        self.pool_in_use = pool_in_use
        self.pool_pages = pool_pages
        super().__init__(
            f"engine not idle after {max_steps} steps: "
            f"queued rids {self.queued}, running rids {self.running}, "
            f"pool {pool_in_use}/{pool_pages} pages in use")


def build_params(cfg: ArchConfig, rt: Runtime, seed: int = 0):
    """Init (and, for pre-packing sites of the active QuantPlan, pack)
    serving weights.

    Packing is per-site: the plan decides which call sites pre-pack into
    the int4 nibble format (legacy uniform `--quant w4a4_packed` maps to a
    uniform plan).  On Pallas backends packed weights also get their planar
    K-major twin (`prepack_tree`) so the kernels' nibble unpack is
    shift/mask only — the relayout is paid once here, never inside a
    serving step.  To serve from a quantized checkpoint instead, pass
    `checkpoint.restore_quantized(dir, cfg=cfg, rt=rt)[0]` as `params` to
    the engine — the cfg/rt arguments assert the runtime's active plan
    matches the plan the checkpoint was saved with."""
    params = init_model(jax.random.PRNGKey(seed), cfg)
    return pack_for_serving(params, cfg, rt)


class InferenceEngine:
    """submit() requests, step() the world, collect() finished requests."""

    def __init__(self, cfg: ArchConfig, rt: Runtime, sv: ServingConfig,
                 params=None, seed: int = 0, clock=time.time,
                 telemetry: Optional[Telemetry] = None):
        # continuous batching puts rows at different positions: cache writes
        # must scatter per-row, never assume step-aligned DUS
        rt = dataclasses.replace(rt, aligned_decode=False)
        blocks = tuple(cfg.pattern) + tuple(cfg.tail)
        # SSM/LRU state integrates every input token, so left-padded prefill
        # would pollute it: non-attention archs serve through the contiguous
        # layout with exact-length (per-L compiled) prefill instead.
        self._all_attention = all(bt == "A" for bt in blocks)
        assert self._all_attention or sv.layout == "contiguous", (
            f"paged KV serving requires an all-attention arch (got {blocks});"
            " use layout='contiguous'")
        self.cfg, self.rt, self.sv = cfg, rt, sv
        self.clock = clock
        # telemetry bundle: per-engine registry (compare-mode engines don't
        # share counters), trace recorder, recompile sentinel
        self.tm = telemetry if telemetry is not None else Telemetry()
        self.metrics = self.tm.registry
        self.trace = self.tm.trace
        self.trace.lane(0, "engine")
        for s in range(sv.max_batch):
            self.trace.lane(1 + s, f"slot{s}")
        # rid -> (trace t0, slot): open request-residency segment, emitted
        # as one span on the slot's lane when the request retires/preempts
        self._seg: Dict[int, tuple] = {}
        self.params = params if params is not None \
            else build_params(cfg, rt, seed)

        if sv.layout == "paged":
            self.kv = PagedKVCacheManager(sv, metrics=self.metrics)
            # batch=0 template: pool leaves are batch-independent; block
            # tables are rebound per call (inside the jit'd steps) from the
            # device-resident [max_batch, pages_per_seq] table pool.  Rows
            # start at the out-of-bounds sentinel (== num_pages): writes
            # through an unassigned slot drop, reads gather zeros — never
            # physical page 0.
            self.caches = init_paged_caches(cfg, rt, 0, sv)
            self._tbl = jnp.full((sv.max_batch, sv.pages_per_seq),
                                 sv.num_pages, jnp.int32)
            self._tbl0 = np.zeros((0, sv.pages_per_seq), np.int32)
            # rid -> (slot, uploaded page ids): a row re-uploads only when
            # the allocation actually changed.  Keyed on the page-id tuple,
            # not the count — a resumed request re-acquiring refcount-held
            # pages may come back with the same *number* of pages but must
            # still re-upload if the ids (or its slot) differ.
            self._tbl_ver: Dict[int, tuple] = {}
        else:
            self.kv = ContinuousKVCache(sv, metrics=self.metrics)
            self.caches = init_caches(cfg, rt, batch=sv.max_batch,
                                      seq=sv.max_ctx)
        self.scheduler = Scheduler(self.kv, sv.max_batch,
                                   metrics=self.metrics,
                                   max_queue=sv.max_queue)
        # step watchdog (ServingConfig.step_deadline_s): a hung or
        # straggling step becomes a counter, and an exception in strict
        # mode — the same Watchdog the training loop arms
        self._watchdog = (Watchdog(sv.step_deadline_s)
                          if sv.step_deadline_s > 0 else None)
        # chaos hook: an exception planted here is raised at the top of the
        # next step(), before any state mutation, so a retry wrapper
        # (distributed.fault_tolerance.run_with_retries) sees a clean retry
        self._inject_fault: Optional[Exception] = None
        # tuned (bm, bn, bk) tiles for every prefill/decode GEMM and for the
        # fused paged-attention kernels: qdense and kernels.ops resolve
        # blocks through kernels.autotune at trace time, so loading the
        # cache before the first compile is all the wiring needed
        autotune.ensure_loaded()
        self._prefill, self._prefill_tail, self._decode = make_serving_steps(
            cfg, rt, paged=sv.layout == "paged")
        # recompile sentinel: every step function is polled after each call
        # (warmup included), so a compile is always attributed to the
        # bucket shape that triggered it
        self.tm.jit_watch.register("prefill", self._prefill)
        self.tm.jit_watch.register("prefill_tail", self._prefill_tail)
        self.tm.jit_watch.register("decode", self._decode)

        # ragged token-major step: ONE jit whose signature depends only on
        # the padded token budget — batch composition (how many rows are
        # prefill chunks vs decode tokens) never recompiles
        self._ragged = None
        if sv.step == "ragged":
            self._ragged = make_ragged_step(cfg, rt)
            self._budget = sv.budget
            self._slots0 = np.zeros((0,), np.int32)
            # bind zero-length routing leaves now so the cache pytree
            # structure (tbl + slots) is identical on every ragged call
            self.caches = with_token_slots(self.caches, self._tbl0,
                                           self._slots0)
            self.tm.jit_watch.register("ragged", self._ragged)

        self._next_rid = 0
        self._finished: List[Request] = []
        self._all: Dict[int, Request] = {}
        # stats
        self.n_steps = 0
        self.n_decode_tokens = 0
        self.n_prefill_tokens = 0        # tokens actually pushed through prefill
        self.n_prefix_hit_tokens = 0     # prompt/resume tokens served from cache
        # padded-capacity accounting (both step modes): packed = useful rows
        # computed, wasted = padding rows computed and discarded
        self.n_tokens_packed = 0
        self.n_tokens_wasted = 0
        self._last_packed = 0
        self._last_wasted = 0
        self.t_start = None
        self._profile: Optional[Dict] = None
        self._profile_step: Optional[int] = None

    # -------------------------------------------------------------- api --
    def submit(self, prompt, max_new: int, arrival: Optional[float] = None,
               eos_id: Optional[int] = None,
               deadline_s: Optional[float] = None) -> int:
        """Queue a request.  ``deadline_s`` is a TTL relative to now: the
        step-boundary sweep retires the request with outcome=timeout once
        it passes, whether it is still queued or mid-decode.  Raises a
        typed ``ShedError`` when the bounded admission queue
        (``ServingConfig.max_queue``) is full — the retired request is
        still collectable with outcome=shed."""
        rid = self._next_rid
        self._next_rid += 1
        now = self.clock()
        req = Request(rid=rid, prompt=np.asarray(prompt, np.int32),
                      max_new=max_new,
                      arrival=now if arrival is None else arrival,
                      eos_id=eos_id,
                      deadline=(now + deadline_s
                                if deadline_s is not None else None))
        req.t_visible = now
        self._all[rid] = req
        try:
            self.scheduler.submit(req)
        except ShedError:
            req.state, req.outcome, req.t_finish = "finished", SHED, now
            self._finished.append(req)
            self._observe_retire(req)
            raise
        except ValueError:
            # capacity validation failure: a typed `error` retirement, so
            # the outcome taxonomy covers rejected-as-malformed too
            req.state, req.outcome, req.t_finish = "finished", ERROR, now
            self._finished.append(req)
            self._observe_retire(req)
            raise
        self.metrics.counter("requests_submitted_total",
                             "requests accepted into the queue").inc()
        return rid

    def cancel(self, rid: int, outcome: str = CANCELLED) -> bool:
        """Cancel a queued, prefilling, or decoding request.  Its
        refcounted pages are released (shared prefix pages stay warm in the
        pool), the batch slot frees at this step boundary, and the request
        retires with the given outcome, collectable via collect().
        Returns False when rid is unknown or already retired."""
        req = self._all.get(rid)
        if req is None or req.t_finish is not None:
            return False
        retired = self.scheduler.cancel(rid, self.clock(), outcome)
        if retired is None:
            return False
        self._finish_aborted(retired)
        return True

    def collect(self) -> List[Request]:
        out, self._finished = self._finished, []
        return out

    def warmup(self, prompt_lens=()) -> None:
        """Compile every expected step signature (one prefill per prompt
        bucket, one decode per batch bucket) before the measured window, so
        latency/throughput stats don't absorb multi-second jit compiles.
        Dummy calls use position -1 everywhere: every cache write is dropped
        and pool/cache state is untouched.  Resumed prefixes can still hit a
        new prompt bucket mid-run; that compile is attributed to the run.

        Ragged mode has exactly ONE signature — the token budget — so
        warmup is one dummy call regardless of the trace's prompt mix."""
        if self._ragged is not None:
            self._warm_ragged()
            return
        for L in sorted({self._prompt_pad(len_) for len_ in prompt_lens}):
            tokens = jnp.zeros((1, L), jnp.int32)
            positions = jnp.full((1, L), -1, jnp.int32)
            if self.sv.layout == "paged":
                _, self.caches = self._prefill(
                    self.params, tokens, self.caches, positions,
                    self._tbl, jnp.zeros((1,), jnp.int32))
                self._strip_tables()
                self._poll_jit("prefill", (1, L))
                if self.sv.prefix_cache:
                    # prefix hits run the tail-prefill step over the same
                    # bucket set (a tail can also land in a smaller bucket
                    # mid-run; that compile is attributed to the run)
                    _, self.caches = self._prefill_tail(
                        self.params, tokens, self.caches, positions,
                        self._tbl, jnp.zeros((1,), jnp.int32))
                    self._strip_tables()
                    self._poll_jit("prefill_tail", (1, L))
            else:
                row = init_caches(self.cfg, self.rt, batch=1,
                                  seq=self.sv.max_ctx)
                self._prefill(self.params, tokens, row, positions)
                self._poll_jit("prefill", (1, L))
        for nb in self.sv.buckets:
            tok = jnp.zeros((nb, 1), jnp.int32)
            pos = jnp.full((nb, 1), -1, jnp.int32)
            if self.sv.layout == "paged":
                _, self.caches = self._decode(
                    self.params, tok, self.caches, pos,
                    self._tbl, jnp.zeros((nb,), jnp.int32))
                self._strip_tables()
            else:
                sub = gather_rows(self.caches, [0] * nb)
                self._decode(self.params, tok, sub, pos)
            self._poll_jit("decode", (nb, 1))

    def _warm_ragged(self) -> None:
        """Compile the single ragged signature at the current budget.  All
        positions/slots are -1 (pure padding): writes drop, pool untouched."""
        T = self._budget
        _, self.caches = self._ragged(
            self.params, jnp.zeros((1, T), jnp.int32), self.caches,
            jnp.full((1, T), -1, jnp.int32), self._tbl,
            jnp.full((T,), -1, jnp.int32),
            jnp.full((self.sv.max_batch,), -1, jnp.int32))
        self._strip_tables()
        self._poll_jit("ragged", (1, T))

    def _grow_budget(self, need: int) -> None:
        """The running set's decode tokens alone exceed the budget (only
        possible with an explicit tiny token_budget): double to fit, compile
        the new signature, and re-baseline the sentinel.  The growth lands
        in the `compiles` count — never in steady_state, which stays the
        zero-recompiles guarantee the ragged mode exists for."""
        new = self._budget
        while new < need:
            new *= 2
        self._budget = new
        self.metrics.counter(
            "ragged_budget_grows_total",
            "token-budget doublings (one fresh compile each)").inc()
        self._warm_ragged()
        self.tm.jit_watch.absorb("ragged")

    def inject_step_fault(self, exc: Exception) -> None:
        """Chaos hook: raise `exc` at the top of the next step(), before
        any scheduler/pool mutation — so wrapping step() in
        ``run_with_retries`` retries against unchanged state."""
        self._inject_fault = exc

    def step(self) -> int:
        """One decode-step boundary; returns the number of running requests
        after the step (0 = idle).

        Lifecycle work happens here, outside the jit'd bodies: injected
        faults fire before any mutation (clean retries), the deadline sweep
        retires overdue requests with outcome=timeout before admission can
        spend pages on them, and the optional step watchdog
        (``ServingConfig.step_deadline_s``) turns a hung/straggling step
        into a counter — or a typed ``StepDeadlineExceeded`` in strict
        mode.  All of it is host-side: the donated single-signature jits
        and the zero-steady-state-recompile guarantee are untouched."""
        if self._inject_fault is not None:
            exc, self._inject_fault = self._inject_fault, None
            raise exc
        for req in self.scheduler.expire(self.clock()):
            self._finish_aborted(req)
        wd = self._watchdog
        if wd is not None:
            wd.arm()
        try:
            n = (self._step_ragged() if self._ragged is not None
                 else self._step_bucketed())
        finally:
            if wd is not None:
                wd.disarm()
        if wd is not None and wd.fired.is_set():
            self.metrics.counter(
                "serving_step_deadline_exceeded_total",
                "engine steps that overran the watchdog deadline").inc()
            if self.sv.step_deadline_strict:
                raise StepDeadlineExceeded(
                    f"serving step {self.n_steps - 1} exceeded "
                    f"{self.sv.step_deadline_s:.3f}s deadline")
        return n

    def _step_bucketed(self) -> int:
        t0 = time.perf_counter()
        tt0 = self.trace.now()
        now = self.clock()
        if self.t_start is None:
            self.t_start = now
        admitted = self.scheduler.admit(now)
        n_tail = sum(1 for r in admitted if r.n_cached)
        for req in admitted:
            self._prefill_request(req)
        self._retire()                 # a 1-token request is done at prefill
        for req in self.scheduler.ensure_decode():
            # recompute-style preemption ends the slot residency: close the
            # segment so the timeline shows the slot going dark
            seg = self._seg.pop(req.rid, None)
            if seg is not None:
                self.trace.complete(f"r{req.rid}", 1 + seg[1], seg[0],
                                    rid=req.rid, outcome="preempted",
                                    gen=len(req.tokens))
        batch = self.scheduler.batch()
        if batch:
            self._decode_batch(batch)
        self.n_steps += 1
        self._retire()
        self._observe_step(t0, tt0, admitted, n_tail, batch)
        return len(self.scheduler.running)

    def _step_ragged(self) -> int:
        """One ragged token-major step: admit, plan a token budget's worth of
        work (decode tokens first, then prefill chunks), run ONE jit over the
        flat pack, apply emissions.  Unlike the bucketed path there is no
        per-admission prefill call — an admitted request's prefix simply
        drains through the planner as chunks, possibly across several steps,
        interleaved with everyone else's decode tokens."""
        t0 = time.perf_counter()
        tt0 = self.trace.now()
        now = self.clock()
        if self.t_start is None:
            self.t_start = now
        admitted = self.scheduler.admit(now)
        n_tail = sum(1 for r in admitted if r.n_cached)
        for req in admitted:
            # prefix-cache hits are realized at admission (the planner only
            # ever feeds prefix[n_cached:]) — account them here, where the
            # bucketed path accounts them inside _prefill_request
            hit = req.n_cached
            self.n_prefix_hit_tokens += hit
            self.metrics.counter(
                "prefix_hit_tokens_total",
                "prompt/resume tokens served from cached pages").inc(hit)
            self._seg.setdefault(req.rid, (self.trace.now(), req.slot))
        for req in self.scheduler.ensure_decode():
            seg = self._seg.pop(req.rid, None)
            if seg is not None:
                self.trace.complete(f"r{req.rid}", 1 + seg[1], seg[0],
                                    rid=req.rid, outcome="preempted",
                                    gen=len(req.tokens))
        # the budget must cover every decode token plus one prefill-chunk
        # slot whenever a prefill-phase request is running — a saturated
        # decode set would otherwise starve later slots indefinitely (the
        # planner serves decode tokens in slot order, so the same requests
        # win every step).  Only reachable with an explicit token_budget
        # below max_batch: grow, compile the new signature once, and
        # re-baseline the sentinel so steady_state stays zero.
        running = self.scheduler.running.values()
        n_decoding = sum(1 for r in running if r.decoding)
        need = n_decoding + (1 if any(not r.decoding for r in running) else 0)
        if need > self._budget:
            self._grow_budget(need)
        plan = self.scheduler.plan_tokens(self._budget)
        if plan:
            self._ragged_exec(plan)
        self.n_steps += 1
        self._retire()
        self._observe_step(t0, tt0, admitted, n_tail,
                           [r for r, _, _ in plan if r.decoding])
        return len(self.scheduler.running)

    def _ragged_exec(self, plan) -> None:
        """Pack the planned (req, start, n) chunks into the flat [1, T]
        buffers and run the ragged step.  Every row's KV is written through
        its block table *before* attention (write-then-attend), so one mask
        rule — key position <= query position — is exactly causal for
        prefill chunks and exactly last-token for decode rows."""
        T = self._budget
        mb = self.sv.max_batch
        tokens = np.zeros((1, T), np.int32)
        positions = np.full((1, T), -1, np.int32)   # -1 = pad: writes drop
        slots = np.full((T,), -1, np.int32)
        emit_rows = np.full((mb,), -1, np.int32)
        used = 0
        for req, start, n in plan:
            tokens[0, used:used + n] = req.prefix[start:start + n]
            positions[0, used:used + n] = np.arange(start, start + n)
            slots[used:used + n] = req.slot
            if start + n == len(req.prefix):
                # chunk reaches the prefix end: this row's logits emit the
                # request's next token (for decode rows, n == 1, always)
                emit_rows[req.slot] = used + n - 1
            used += n
        self._observe_packing(used, T)
        self._sync_tables([r for r, _, _ in plan])
        tp0 = self.trace.now()
        nxt, self.caches = self._ragged(
            self.params, jnp.asarray(tokens), self.caches,
            jnp.asarray(positions), self._tbl, jnp.asarray(slots),
            jnp.asarray(emit_rows))
        self._strip_tables()
        self._poll_jit("ragged", (1, T))
        # the step's ONE sanctioned device->host sync: token readback
        nxt = np.asarray(nxt)  # repro: ignore[host-sync-in-hot-path]
        ps = self.sv.page_size
        for req, start, n in plan:
            end = start + n
            if req.decoding:
                self.n_decode_tokens += 1
                self.metrics.counter(
                    "decode_tokens_total",
                    "tokens emitted by decode steps").inc()
            else:
                self.n_prefill_tokens += n
                self.metrics.counter(
                    "prefill_tokens_total",
                    "tokens pushed through prefill").inc(n)
                if self.trace.enabled:
                    self.trace.complete("chunk_prefill", 1 + req.slot, tp0,
                                        rid=req.rid, tokens=n, start=start)
            req.n_cached = end
            if emit_rows[req.slot] >= 0:
                if not req.decoding:
                    # prefill just completed: register its full pages before
                    # the emitted token joins the prefix (mirrors the
                    # bucketed engine's post-prefill registration)
                    self.kv.register_upto(req.rid, req.prefix, end)
                req.tokens.append(int(nxt[req.slot]))
                if req.t_first is None:
                    req.t_first = self.clock()
                req.decoding = True
                if end % ps == 0 and len(req.tokens) > 1:
                    # a generated-token page just filled (decode rows only —
                    # end counts the token written this step)
                    self.kv.register_upto(req.rid, req.prefix, end)

    def _observe_step(self, t0: float, tt0: float, admitted: List[Request],
                      n_tail: int, batch: List[Request]) -> None:
        """Per-step telemetry: wall time + batch composition into the
        registry, occupancy gauges sampled at the step boundary, and the
        engine-lane step span."""
        m = self.metrics
        m.counter("steps_total", "engine decode-step boundaries").inc()
        m.histogram("step_wall_us",
                    "wall time per engine step").observe(
                        (time.perf_counter() - t0) * 1e6)
        if batch:
            m.histogram("decode_batch_size", "running rows per decode step",
                        buckets=COUNT_BUCKETS).observe(len(batch))
        m.gauge("queue_depth",
                "requests waiting for admission").set(
                    len(self.scheduler.waiting))
        m.gauge("running_requests",
                "requests in the decode batch").set(
                    len(self.scheduler.running))
        # token utilization of this step's padded capacity (both step
        # modes): useful rows over useful+padding rows computed since the
        # previous boundary
        du = self.n_tokens_packed - self._last_packed
        dw = self.n_tokens_wasted - self._last_wasted
        self._last_packed = self.n_tokens_packed
        self._last_wasted = self.n_tokens_wasted
        if du + dw:
            m.gauge("token_utilization",
                    "useful fraction of the step's padded token capacity"
                    ).set(du / (du + dw))
        if self.sv.layout == "paged":
            m.gauge("kv_pool_in_use_pages",
                    "pages held by running requests").set(self.kv.in_use)
            m.gauge("kv_pool_warm_pages",
                    "refcount-0 pages still indexed").set(len(self.kv.warm))
            m.gauge("kv_pool_blank_pages",
                    "free pages with no content").set(len(self.kv.blank))
            m.gauge("kv_pool_occupancy",
                    "in-use fraction of the page pool").set(
                        self.kv.in_use / self.sv.num_pages)
            m.gauge("kv_pool_high_water_pages",
                    "peak concurrent in-use pages").set(self.kv.high_water)
        if self.trace.enabled:
            self.trace.complete(
                "step", 0, tt0,
                decode_rows=len(batch),
                prefills=len(admitted) - n_tail, tail_prefills=n_tail,
                queue_depth=len(self.scheduler.waiting),
                pool_in_use=getattr(self.kv, "in_use", 0))

    def _retire(self) -> None:
        now = self.clock()
        for req in list(self.scheduler.running.values()):
            if req.done:
                self.scheduler.finish(req, now)
                self._finished.append(req)
                self._observe_retire(req)

    def _finish_aborted(self, req: Request) -> None:
        """Land a scheduler-aborted request (cancel, deadline expiry) in the
        collect() queue with its outcome telemetry — the same retirement
        path a clean finish takes, minus scheduler.finish (the scheduler
        already evicted it)."""
        self._finished.append(req)
        self._observe_retire(req)

    def _observe_retire(self, req: Request) -> None:
        """Per-request retirement telemetry, recorded the moment t_finish is
        stamped.  Latency histograms and the retire counter carry the typed
        ``outcome`` label (ok|cancelled|timeout|shed|error) so dashboards
        separate clean finishes from lifecycle aborts without a second
        registry; ``requests_finished_total`` stays ok-only (it means what
        it always meant).  TTFT/ITL — the histograms the SLO scheduler and
        autoscaling signal (ROADMAP item 3) will consume — record for any
        outcome that got far enough to have the timestamps."""
        m = self.metrics
        out = req.outcome or ERROR
        m.counter("requests_retired_total", "requests retired, any outcome",
                  outcome=out).inc()
        if out == OK:
            m.counter("requests_finished_total",
                      "requests fully decoded").inc()
        elif out == CANCELLED:
            m.counter("serving_cancelled_total",
                      "requests cancelled before finishing").inc()
        elif out == TIMEOUT:
            m.counter("serving_timeout_total",
                      "requests retired past their deadline").inc()
        elif out == SHED:
            m.counter("serving_shed_total",
                      "requests shed by the bounded admission queue").inc()
        m.histogram("request_latency_us",
                    "submit-to-retire wall time", outcome=out).observe(
                        (req.t_finish - req.t_visible) * 1e6)
        if req.t_first is not None:
            m.histogram("ttft_us", "time to first token",
                        outcome=out).observe(
                            (req.t_first - req.t_visible) * 1e6)
            if len(req.tokens) > 1:
                m.histogram("itl_us",
                            "mean inter-token latency per request",
                            outcome=out).observe(
                                (req.t_finish - req.t_first) * 1e6
                                / (len(req.tokens) - 1))
        seg = self._seg.pop(req.rid, None)
        if seg is not None:
            self.trace.complete(f"r{req.rid}", 1 + seg[1], seg[0],
                                rid=req.rid, outcome=out,
                                gen=len(req.tokens),
                                preempts=req.n_preempts)

    def run_until_idle(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if self.step() == 0 and self.scheduler.idle:
                return
        self.metrics.counter(
            "serving_engine_stuck_total",
            "run_until_idle step-budget exhaustions").inc()
        raise EngineStuckError(
            max_steps,
            [r.rid for r in self.scheduler.waiting],
            list(self.scheduler.running),
            getattr(self.kv, "in_use", 0),
            self.sv.num_pages if self.sv.layout == "paged" else 0)

    # -------------------------------------------------------- internals --
    def _observe_packing(self, used: int, capacity: int) -> None:
        """Account one padded launch: `used` useful token rows out of
        `capacity` computed.  The delta feeds the per-step
        ``token_utilization`` gauge; the counter is the cumulative padding
        bill a budget/bucket tuning pass wants to shrink."""
        wasted = max(capacity - used, 0)
        self.n_tokens_packed += used
        self.n_tokens_wasted += wasted
        self.metrics.counter(
            "padding_tokens_wasted_total",
            "padding token rows computed and discarded").inc(wasted)

    def _poll_jit(self, name: str, shape) -> None:
        """Poll the recompile sentinel right after a step-function call,
        attributing any jit cache growth to `shape` (the bucket signature
        of the call that just ran)."""
        self.tm.jit_watch.after_call(name, shape, step=self.n_steps)

    def _prompt_pad(self, L: int) -> int:
        """Prompt lengths are bucketed (fewer compiles) for attention archs;
        SSM/LRU state integrates pad tokens, so those prefill at exact L."""
        return self.sv.prompt_bucket(L) if self._all_attention else L

    def _strip_tables(self) -> None:
        """Rebind the batch-0 table template after a paged step so the
        stored cache tree's signature never depends on the last bucket.
        Ragged mode also carries zero-length token-slot leaves — strip both
        so every ragged call sees the identical cache pytree."""
        if self._ragged is not None:
            self.caches = with_token_slots(self.caches, self._tbl0,
                                           self._slots0)
        else:
            self.caches = with_block_tables(self.caches, self._tbl0)

    def _sync_tables(self, batch: List[Request]) -> None:
        """Upload block-table rows whose page allocation changed since the
        last upload (admission, page growth).  This is the only host->device
        block-table traffic — steady-state decode uploads nothing."""
        for req in batch:
            ver = (req.slot, tuple(self.kv.pages.get(req.rid, ())))
            if self._tbl_ver.get(req.rid) != ver:
                self._tbl = self._tbl.at[req.slot].set(
                    jnp.asarray(self.kv.table_row(req.rid)))
                self._tbl_ver[req.rid] = ver
                self.metrics.counter(
                    "block_table_uploads_total",
                    "host->device block-table row uploads").inc()
        # drop versions of finished/preempted requests so dead entries don't
        # accumulate.  Correctness doesn't ride on this prune: versions key
        # on (slot, page ids), so a resumed rid re-admitting with the very
        # same refcount-held pages into the same slot genuinely needs no
        # re-upload, and any change in slot or ids forces one.
        running = self.scheduler.running
        for rid in [r for r in self._tbl_ver if r not in running]:
            del self._tbl_ver[rid]

    def _prefill_request(self, req: Request) -> None:
        """Prefill a (re-)admitted request's uncached prefix tail (batch of
        one, left-padded to a power-of-two bucket) and emit its first token
        from the prefill logits.

        The scheduler's admission set ``req.n_cached`` to the prefix-cache
        hit length (0 without a hit): the cached prefix already lives in
        shared pages, so only ``prefix[hit:]`` flows through the model —
        via the tail-prefill step, whose suffix queries attend over the
        gathered page pool instead of just the in-flight K/V."""
        prefix = req.prefix
        L = len(prefix)
        hit = req.n_cached                     # page-aligned, < L by design
        tail = prefix[hit:]
        n = len(tail)
        Lb = self._prompt_pad(n)
        tokens = np.zeros((1, Lb), np.int32)
        tokens[0, Lb - n:] = tail
        base = np.arange(Lb, dtype=np.int32) - (Lb - n)
        # pad rows must stay negative (dropped writes / masked queries) even
        # after the hit offset shifts the real tail to hit..L-1
        positions = np.where(base >= 0, base + hit, -1)[None, :]

        # open the slot-residency segment (resumes re-open a fresh one) and
        # record this prefill as a span at its start
        self._seg.setdefault(req.rid, (self.trace.now(), req.slot))
        tp0 = self.trace.now()
        if self.sv.layout == "paged":
            self._sync_tables([req])
            step = self._prefill_tail if hit else self._prefill
            tok, self.caches = step(
                self.params, jnp.asarray(tokens), self.caches,
                jnp.asarray(positions), self._tbl,
                jnp.asarray([req.slot], jnp.int32))
            self._strip_tables()
            self._poll_jit("prefill_tail" if hit else "prefill", (1, Lb))
        else:
            # a fresh init row IS the reset: prefill into it, then scatter
            # the row into the slot (evicting any previous tenant's state)
            row = init_caches(self.cfg, self.rt, batch=1, seq=self.sv.max_ctx)
            tok, row = self._prefill(
                self.params, jnp.asarray(tokens), row, jnp.asarray(positions))
            self.caches = scatter_rows(self.caches, row, [req.slot])
            self._poll_jit("prefill", (1, Lb))
        self.trace.complete("tail_prefill" if hit else "prefill",
                            1 + req.slot, tp0, rid=req.rid, tokens=n,
                            hit=hit, bucket=Lb)

        req.n_cached = L
        self.n_prefill_tokens += n
        self.n_prefix_hit_tokens += hit
        m = self.metrics
        m.counter("prefill_tokens_total",
                  "tokens pushed through prefill").inc(n)
        if hit:
            m.counter("tail_prefill_tokens_total",
                      "prefill tokens behind a prefix-cache hit").inc(n)
        m.counter("prefix_hit_tokens_total",
                  "prompt/resume tokens served from cached pages").inc(hit)
        self._observe_packing(n, Lb)
        self.kv.register_upto(req.rid, prefix, L)   # index newly-full pages
        req.tokens.append(int(tok[0]))
        if req.t_first is None:
            req.t_first = self.clock()

    def _decode_batch(self, batch: List[Request]) -> None:
        """One decode step over the running set, padded to a bucket."""
        n = len(batch)
        nb = self.sv.decode_bucket(n)
        tok = np.zeros((nb, 1), np.int32)
        pos = np.full((nb, 1), -1, np.int32)
        for i, req in enumerate(batch):
            tok[i, 0] = req.tokens[-1]      # feed the newest generated token
            pos[i, 0] = req.n_cached        # ... at the next cache position

        if self.sv.layout == "paged":
            # pad rows point at slot 0: their positions are -1, so writes
            # drop and their (masked) attention output is discarded
            self._sync_tables(batch)
            slots = np.zeros((nb,), np.int32)
            slots[:n] = [r.slot for r in batch]
            nxt, self.caches = self._decode(
                self.params, jnp.asarray(tok), self.caches,
                jnp.asarray(pos), self._tbl, jnp.asarray(slots))
            self._strip_tables()
        else:
            rows = [r.slot for r in batch] \
                + [self.sv.max_batch - 1] * (nb - n)   # pads write nothing
            sub = gather_rows(self.caches, rows)
            nxt, sub = self._decode(
                self.params, jnp.asarray(tok), sub, jnp.asarray(pos))
            # scatter only the active rows back (a pad row may alias an
            # active slot, and duplicate scatter indices would race)
            self.caches = scatter_rows(
                self.caches, gather_rows(sub, np.arange(n)), rows[:n])
        self._poll_jit("decode", (nb, 1))
        self._observe_packing(n, nb)
        self.metrics.counter("decode_tokens_total",
                             "tokens emitted by decode steps").inc(n)
        # the step's ONE sanctioned device->host sync: token readback
        nxt = np.asarray(nxt)  # repro: ignore[host-sync-in-hot-path]
        ps = self.sv.page_size
        for i, req in enumerate(batch):
            req.n_cached += 1
            req.tokens.append(int(nxt[i]))
            if self.sv.layout == "paged" and req.n_cached % ps == 0:
                # a generated-token page just filled: index it so preempted
                # or follow-up requests sharing this prefix can hit it
                self.kv.register_upto(req.rid, req.prefix, req.n_cached)
        self.n_decode_tokens += n

    # ------------------------------------------------------- stop/resume --
    def snapshot(self) -> Dict:
        """Freeze the engine at a step boundary: every request's progress,
        the scheduler's queues/slots, the page pool's full bookkeeping, the
        device KV pool and block tables (pulled to host numpy), and the
        engine counters.  `InferenceEngine.restore(snap)` builds a fresh
        engine that continues *bit-identically* — restored requests emit
        exactly the tokens the uninterrupted run would have (the device
        pool is captured verbatim, so nothing is recomputed).

        Call between steps (never from inside a step callback).  The dict
        is in-memory/same-process state: config objects are held by
        reference and the prefix index carries Python content hashes, which
        are only stable across processes with PYTHONHASHSEED pinned — to
        persist across processes, pickle it from a pinned interpreter."""
        def _req(req: Request) -> Dict:
            d = {f.name: getattr(req, f.name)
                 for f in dataclasses.fields(Request)}
            d["prompt"] = np.array(d["prompt"], np.int32)
            d["tokens"] = list(d["tokens"])
            return d

        sch = self.scheduler
        return {
            "cfg": self.cfg, "rt": self.rt, "sv": self.sv,
            "requests": {rid: _req(r) for rid, r in self._all.items()},
            "finished": [r.rid for r in self._finished],
            "waiting": [r.rid for r in sch.waiting],
            "running": list(sch.running),          # insertion order
            "free_slots": list(sch._free_slots),   # heap layout, verbatim
            "admit_counter": sch._admit_counter,
            "n_preemptions": sch.n_preemptions,
            "kv": self.kv.state(),
            "caches": jax.tree.map(np.asarray, self.caches),
            "tbl": np.asarray(self._tbl),
            "budget": self._budget if self._ragged is not None else None,
            "next_rid": self._next_rid,
            "counters": {
                "n_steps": self.n_steps,
                "n_decode_tokens": self.n_decode_tokens,
                "n_prefill_tokens": self.n_prefill_tokens,
                "n_prefix_hit_tokens": self.n_prefix_hit_tokens,
                "n_tokens_packed": self.n_tokens_packed,
                "n_tokens_wasted": self.n_tokens_wasted,
                "t_start": self.t_start,
            },
        }

    @classmethod
    def restore(cls, snap: Dict, params=None, seed: int = 0,
                clock=time.time, telemetry: Optional[Telemetry] = None
                ) -> "InferenceEngine":
        """Build an engine from a `snapshot()` and resume where it stopped.
        Weights are NOT in the snapshot — pass the same `params` (or the
        same `seed`, which re-inits them deterministically).  The restored
        engine's step functions are fresh jits: their first calls compile
        (first-seen shapes, counted as compiles), but the zero
        steady-state-recompile guarantee holds from there."""
        eng = cls(snap["cfg"], snap["rt"], snap["sv"], params=params,
                  seed=seed, clock=clock, telemetry=telemetry)
        eng._load_snapshot(snap)
        return eng

    def _load_snapshot(self, snap: Dict) -> None:
        reqs: Dict[int, Request] = {}
        for rid, d in snap["requests"].items():
            d = dict(d)
            d["prompt"] = np.array(d["prompt"], np.int32)
            d["tokens"] = list(d["tokens"])
            reqs[rid] = Request(**d)
        self._all = reqs
        self._finished = [reqs[r] for r in snap["finished"]]
        sch = self.scheduler
        sch.waiting = deque(reqs[r] for r in snap["waiting"])
        sch.running = {r: reqs[r] for r in snap["running"]}
        sch._free_slots = list(snap["free_slots"])
        sch._admit_counter = snap["admit_counter"]
        sch.n_preemptions = snap["n_preemptions"]
        self.kv.load_state(snap["kv"])
        if self._ragged is not None and snap["budget"] is not None \
                and snap["budget"] != self._budget:
            # match the source engine's (possibly grown) budget so the plan
            # packs identically; the signature compiles on first use
            self._budget = snap["budget"]
        self.caches = jax.tree.map(jnp.asarray, snap["caches"])
        self._strip_tables()
        if self.sv.layout == "paged":
            self._tbl = jnp.asarray(snap["tbl"])
            # empty version map => _sync_tables re-uploads rows for running
            # requests on their next batch; correct either way, since
            # versions key on (slot, page ids)
            self._tbl_ver = {}
        self._next_rid = snap["next_rid"]
        c = snap["counters"]
        self.n_steps = c["n_steps"]
        self.n_decode_tokens = c["n_decode_tokens"]
        self.n_prefill_tokens = c["n_prefill_tokens"]
        self.n_prefix_hit_tokens = c["n_prefix_hit_tokens"]
        self.n_tokens_packed = self._last_packed = c["n_tokens_packed"]
        self.n_tokens_wasted = self._last_wasted = c["n_tokens_wasted"]
        self.t_start = c["t_start"]
        sch.check_invariants()
        self.kv.check_invariants()

    # ----------------------------------------------------------- profile --
    def profile(self, reps: int = 3) -> Dict:
        """Attribute one full-context decode step's cost: the whole jit'd
        step is timed against the attention op alone (the same kernels.ops
        dispatch the model traces), so a perf regression can be pinned on
        attention vs the GEMM/rest of the step.  Call when idle — the probe
        steps write through (stale) block tables and scratch the pool.
        The result lands in ``stats()["profile"]``."""
        import time as _time

        def _best_us(fn):
            jax.block_until_ready(fn())
            ts = []
            for _ in range(reps):
                t0 = _time.perf_counter()
                jax.block_until_ready(fn())
                ts.append((_time.perf_counter() - t0) * 1e6)
            return float(min(ts))

        nb = self.sv.max_batch
        cfg, sv = self.cfg, self.sv
        tok = jnp.zeros((nb, 1), jnp.int32)
        pos = jnp.full((nb, 1), sv.max_ctx - 1, jnp.int32)
        last = jnp.full((nb,), sv.max_ctx - 1, jnp.int32)
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.standard_normal((nb, cfg.n_heads, cfg.hd)),
                        jnp.bfloat16)

        if self._ragged is not None:
            # ragged mode: the step IS the ragged jit — time it at the
            # budget, all-padding rows (writes drop, pool untouched)
            T = self._budget
            rtok = jnp.zeros((1, T), jnp.int32)
            rpos = jnp.full((1, T), -1, jnp.int32)
            rslots = jnp.full((T,), -1, jnp.int32)
            remit = jnp.full((nb,), -1, jnp.int32)

            def step():
                nxt, self.caches = self._ragged(
                    self.params, rtok, self.caches, rpos, self._tbl,
                    rslots, remit)
                self._strip_tables()
                return nxt
        elif sv.layout == "paged":
            def step():
                nxt, self.caches = self._decode(
                    self.params, tok, self.caches, pos, self._tbl,
                    jnp.zeros((nb,), jnp.int32))
                self._strip_tables()
                return nxt
        else:
            rows = list(range(nb))

            def step():
                sub = gather_rows(self.caches, rows)
                nxt, sub = self._decode(self.params, tok, sub, pos)
                self.caches = scatter_rows(self.caches, sub, rows)
                return nxt

        # time the step first: it donates the cache pool, so the attention
        # probe must capture its pool references afterwards
        step_us = _best_us(step)

        from repro.models.attention import _cache_read, attention_core
        layer = jax.tree.map(lambda l: l[0], self.caches["rep"])
        attn = next((blk["attn"] for blk in layer.values() if "attn" in blk),
                    None)
        if attn is None:
            # SSM/LRU stack: no attention blocks to attribute — the whole
            # step is GEMM + recurrence
            self._profile = {
                "decode_step_us": round(step_us, 1),
                "attn_us": 0.0,
                "gemm_other_us": round(step_us, 1),
                "attn_frac": 0.0,
                "at_step": self.n_steps,
            }
            self._profile_step = self.n_steps
            self.tm.jit_watch.absorb()
            return self._profile
        if sv.layout == "paged":
            from repro.kernels import ops
            from repro.serving.kv_pages import paged_read

            tbl = self._tbl[:nb]
            if self._ragged is not None:
                T = self._budget
                qT = jnp.asarray(
                    rng.standard_normal((T, cfg.n_heads, cfg.hd)),
                    jnp.bfloat16)
                tslots = jnp.zeros((T,), jnp.int32)
                tpos = jnp.full((T,), sv.max_ctx - 1, jnp.int32)

                def attn_op():
                    return ops.ragged_paged_attention(
                        qT, attn["k"], attn["v"], self._tbl, tslots, tpos,
                        attn.get("k_scale"), attn.get("v_scale"),
                        window=cfg.local_window)
            elif self.rt.paged_attn == "fused":
                def attn_op():
                    return ops.paged_decode_attention(
                        q, attn["k"], attn["v"], tbl, last,
                        attn.get("k_scale"), attn.get("v_scale"),
                        window=cfg.local_window)
            else:
                def attn_op():
                    kf, vf, kpos = paged_read(dict(attn, tbl=tbl), last)
                    return attention_core(
                        q[:, None], kf, vf, q_positions=last[:, None],
                        k_positions=kpos, window=cfg.local_window,
                        impl="full", chunk_q=self.rt.attn_chunk_q)
        else:
            attn = {k_: v_[:nb] for k_, v_ in attn.items() if k_ != "pos"}
            # every cached slot marked valid: the probe times a full-window
            # attention regardless of how much real state the run left
            # behind (kpos carries the ring size, which is < max_ctx for
            # sliding-window configs)
            kpos = jnp.broadcast_to(
                jnp.arange(attn["kpos"].shape[1], dtype=jnp.int32),
                attn["kpos"].shape)
            attn.pop("kpos")

            def attn_op():
                kf, vf = _cache_read(attn)
                return attention_core(
                    q[:, None], kf, vf, q_positions=last[:, None],
                    k_positions=kpos, window=cfg.local_window,
                    impl="full", chunk_q=self.rt.attn_chunk_q)

        attn_us = _best_us(jax.jit(attn_op)) * cfg.n_layers
        self._profile = {
            "decode_step_us": round(step_us, 1),
            "attn_us": round(attn_us, 1),
            "gemm_other_us": round(max(step_us - attn_us, 0.0), 1),
            "attn_frac": round(min(attn_us / step_us, 1.0), 4)
            if step_us else None,
            "at_step": self.n_steps,
        }
        self._profile_step = self.n_steps
        # the probe calls above may have compiled new signatures (a probe
        # batch can hit an unvisited bucket): re-baseline the sentinel so
        # those compiles don't masquerade as the next real step's recompile
        self.tm.jit_watch.absorb()
        return self._profile

    # ------------------------------------------------------------- stats --
    def stats(self) -> Dict:
        retired = [r for r in self._all.values() if r.t_finish is not None]
        # latency aggregates describe *clean* finishes only — a storm of
        # instantly-cancelled requests must not drag p50 toward zero
        done = [r for r in retired if r.outcome == OK]
        outcomes: Dict[str, int] = {}
        for r in retired:
            out = r.outcome or ERROR
            outcomes[out] = outcomes.get(out, 0) + 1
        lat = [r.t_finish - r.t_visible for r in done]
        # `is not None`, not truthiness: a t_first of exactly 0.0 (fake
        # clocks, epoch-zero traces) is a real first-token time
        ttft = [r.t_first - r.t_visible for r in done
                if r.t_first is not None]
        wall = (self.clock() - self.t_start) \
            if self.t_start is not None else 0.0
        # every derived latency field degrades to None with zero finished
        # requests — callers see requests_finished: 0 and no fake numbers
        pct = (lambda xs, q: float(np.percentile(xs, q)) if xs else None)
        mean = (lambda xs: float(np.mean(xs)) if xs else None)
        demand = self.n_prefill_tokens + self.n_prefix_hit_tokens
        capacity = self.n_tokens_packed + self.n_tokens_wasted
        return {
            "layout": self.sv.layout,
            "step_mode": self.sv.step,
            **({"token_budget": self._budget}
               if self._ragged is not None else {}),
            "padding_tokens_wasted": self.n_tokens_wasted,
            "token_utilization": (self.n_tokens_packed / capacity
                                  if capacity else None),
            "requests_finished": len(done),
            "requests_retired": len(retired),
            "outcomes": outcomes,
            "requests_preempted": self.scheduler.n_preemptions,
            "steps": self.n_steps,
            "prefill_tokens": self.n_prefill_tokens,
            "tokens_prefilled_saved": self.n_prefix_hit_tokens,
            "prefix_hit_rate": (self.n_prefix_hit_tokens / demand
                                if demand else 0.0),
            "prefix_cache": {
                "enabled": (self.sv.layout == "paged"
                            and self.sv.prefix_cache),
                "lookups": self.kv.n_lookups,
                "hit_tokens": self.kv.n_hit_tokens,
                "evictions": self.kv.n_evictions,
            },
            "decode_tokens": self.n_decode_tokens,
            "wall_s": wall,
            "decode_tok_per_s": self.n_decode_tokens / wall if wall else None,
            "latency_p50_s": pct(lat, 50),
            "latency_p95_s": pct(lat, 95),
            "latency_mean_s": mean(lat),
            "ttft_p50_s": pct(ttft, 50),
            "ttft_p95_s": pct(ttft, 95),
            "ttft_mean_s": mean(ttft),
            "kv_pages_high_water": getattr(self.kv, "high_water", 0),
            "paged_attn": self.rt.paged_attn
            if self.sv.layout == "paged" else None,
            "metrics": self.metrics.snapshot(),
            "recompiles": self.tm.jit_watch.snapshot(),
            **({"profile": self._profile,
                "profile_at_step": self._profile_step}
               if self._profile else {}),
        }
