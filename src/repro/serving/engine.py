"""Inference engine: drives jit'd prefill/decode steps over the scheduled
batch with per-request state tracking and latency/throughput stats.

One `step()` is a decode-step boundary: admit (+prefill) newly-arrived
requests, preempt if the page pool is dry, run one decode step for the
running set, retire finished requests.  Greedy decoding (argmax), which is
what the bit-exactness harness compares across KV layouts.

Batch construction is identical for both layouts — running requests compacted
in slot order, padded to the nearest bucket with inactive rows (position -1:
attention masks them and their cache writes are dropped) — so paged and
contiguous runs of the same trace execute the same program shapes and the
same per-row math.  The layouts differ only in where KV bytes live:

  * paged      -- pool + block tables travel with the batch; joining/leaving
                  requests exchange a [pages_per_seq] int row, never KV data.
  * contiguous -- each slot owns a max_ctx row; admission scatters a freshly
                  prefilled row into the full cache (an O(cache) copy that the
                  paged layout exists to avoid — see EXPERIMENTS.md §Serving).
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, Runtime, ServingConfig
from repro.core.quant_plan import pack_for_serving
from repro.kernels import autotune
from repro.launch.steps import make_serving_steps
from repro.models import init_caches, init_model
from repro.serving.kv_pages import (
    ContinuousKVCache,
    PagedKVCacheManager,
    gather_rows,
    init_paged_caches,
    scatter_rows,
    with_block_tables,
)
from repro.serving.scheduler import Request, Scheduler


def build_params(cfg: ArchConfig, rt: Runtime, seed: int = 0):
    """Init (and, for pre-packing sites of the active QuantPlan, pack)
    serving weights.

    Packing is per-site: the plan decides which call sites pre-pack into
    the int4 nibble format (legacy uniform `--quant w4a4_packed` maps to a
    uniform plan).  On Pallas backends packed weights also get their planar
    K-major twin (`prepack_tree`) so the kernels' nibble unpack is
    shift/mask only — the relayout is paid once here, never inside a
    serving step.  To serve from a quantized checkpoint instead, pass
    `checkpoint.restore_quantized(dir, cfg=cfg, rt=rt)[0]` as `params` to
    the engine — the cfg/rt arguments assert the runtime's active plan
    matches the plan the checkpoint was saved with."""
    params = init_model(jax.random.PRNGKey(seed), cfg)
    return pack_for_serving(params, cfg, rt)


class InferenceEngine:
    """submit() requests, step() the world, collect() finished requests."""

    def __init__(self, cfg: ArchConfig, rt: Runtime, sv: ServingConfig,
                 params=None, seed: int = 0, clock=time.time):
        # continuous batching puts rows at different positions: cache writes
        # must scatter per-row, never assume step-aligned DUS
        import dataclasses
        rt = dataclasses.replace(rt, aligned_decode=False)
        blocks = tuple(cfg.pattern) + tuple(cfg.tail)
        # SSM/LRU state integrates every input token, so left-padded prefill
        # would pollute it: non-attention archs serve through the contiguous
        # layout with exact-length (per-L compiled) prefill instead.
        self._all_attention = all(bt == "A" for bt in blocks)
        assert self._all_attention or sv.layout == "contiguous", (
            f"paged KV serving requires an all-attention arch (got {blocks});"
            " use layout='contiguous'")
        self.cfg, self.rt, self.sv = cfg, rt, sv
        self.clock = clock
        self.params = params if params is not None \
            else build_params(cfg, rt, seed)

        if sv.layout == "paged":
            self.kv = PagedKVCacheManager(sv)
            # batch=0 template: pool leaves are batch-independent; block
            # tables are rebound per call via with_block_tables
            self.caches = init_paged_caches(cfg, rt, 0, sv)
        else:
            self.kv = ContinuousKVCache(sv)
            self.caches = init_caches(cfg, rt, batch=sv.max_batch,
                                      seq=sv.max_ctx)
        self.scheduler = Scheduler(self.kv, sv.max_batch)
        # tuned (bm, bn, bk) tiles for every prefill/decode GEMM: qdense
        # resolves blocks through kernels.autotune at trace time, so loading
        # the cache before the first compile is all the wiring needed
        autotune.ensure_loaded()
        self._prefill, self._decode = make_serving_steps(cfg, rt)

        self._next_rid = 0
        self._finished: List[Request] = []
        self._all: Dict[int, Request] = {}
        # stats
        self.n_steps = 0
        self.n_decode_tokens = 0
        self.n_prefill_tokens = 0
        self.t_start = None

    # -------------------------------------------------------------- api --
    def submit(self, prompt, max_new: int, arrival: Optional[float] = None,
               eos_id: Optional[int] = None) -> int:
        rid = self._next_rid
        self._next_rid += 1
        now = self.clock()
        req = Request(rid=rid, prompt=np.asarray(prompt, np.int32),
                      max_new=max_new,
                      arrival=now if arrival is None else arrival,
                      eos_id=eos_id)
        req.t_visible = now
        self._all[rid] = req
        self.scheduler.submit(req)
        return rid

    def collect(self) -> List[Request]:
        out, self._finished = self._finished, []
        return out

    def warmup(self, prompt_lens=()) -> None:
        """Compile every expected step signature (one prefill per prompt
        bucket, one decode per batch bucket) before the measured window, so
        latency/throughput stats don't absorb multi-second jit compiles.
        Dummy calls use position -1 everywhere: every cache write is dropped
        and pool/cache state is untouched.  Resumed prefixes can still hit a
        new prompt bucket mid-run; that compile is attributed to the run."""
        for L in sorted({self._prompt_pad(len_) for len_ in prompt_lens}):
            tokens = jnp.zeros((1, L), jnp.int32)
            positions = jnp.full((1, L), -1, jnp.int32)
            if self.sv.layout == "paged":
                caches = with_block_tables(
                    self.caches, np.zeros((1, self.sv.pages_per_seq)))
                _, self.caches = self._prefill(self.params, tokens, caches,
                                               positions)
            else:
                row = init_caches(self.cfg, self.rt, batch=1,
                                  seq=self.sv.max_ctx)
                self._prefill(self.params, tokens, row, positions)
        for nb in self.sv.buckets:
            tok = jnp.zeros((nb, 1), jnp.int32)
            pos = jnp.full((nb, 1), -1, jnp.int32)
            if self.sv.layout == "paged":
                caches = with_block_tables(
                    self.caches, np.zeros((nb, self.sv.pages_per_seq)))
                _, self.caches = self._decode(self.params, tok, caches, pos)
            else:
                sub = gather_rows(self.caches, [0] * nb)
                self._decode(self.params, tok, sub, pos)

    def step(self) -> int:
        """One decode-step boundary; returns the number of running requests
        after the step (0 = idle)."""
        now = self.clock()
        if self.t_start is None:
            self.t_start = now
        for req in self.scheduler.admit(now):
            self._prefill_request(req)
        self._retire()                 # a 1-token request is done at prefill
        self.scheduler.ensure_decode()
        batch = self.scheduler.batch()
        if batch:
            self._decode_batch(batch)
        self.n_steps += 1
        self._retire()
        return len(self.scheduler.running)

    def _retire(self) -> None:
        now = self.clock()
        for req in list(self.scheduler.running.values()):
            if req.done:
                self.scheduler.finish(req, now)
                self._finished.append(req)

    def run_until_idle(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if self.step() == 0 and self.scheduler.idle:
                return
        raise RuntimeError(f"not idle after {max_steps} steps")

    # -------------------------------------------------------- internals --
    def _prompt_pad(self, L: int) -> int:
        """Prompt lengths are bucketed (fewer compiles) for attention archs;
        SSM/LRU state integrates pad tokens, so those prefill at exact L."""
        return self.sv.prompt_bucket(L) if self._all_attention else L

    def _greedy(self, logits) -> np.ndarray:
        return np.asarray(
            jnp.argmax(logits[:, : self.cfg.vocab], axis=-1), np.int32)

    def _prefill_request(self, req: Request) -> None:
        """Prefill a (re-)admitted request's full prefix (batch of one,
        prompt left-padded to a power-of-two bucket) and emit its first
        token from the prefill logits."""
        prefix = req.prefix
        L = len(prefix)
        Lb = self._prompt_pad(L)
        tokens = np.zeros((1, Lb), np.int32)
        tokens[0, Lb - L:] = prefix
        positions = (np.arange(Lb, dtype=np.int32) - (Lb - L))[None, :]

        if self.sv.layout == "paged":
            caches = with_block_tables(self.caches,
                                       self.kv.table_row(req.rid)[None])
            logits, self.caches = self._prefill(
                self.params, jnp.asarray(tokens), caches,
                jnp.asarray(positions))
        else:
            # a fresh init row IS the reset: prefill into it, then scatter
            # the row into the slot (evicting any previous tenant's state)
            row = init_caches(self.cfg, self.rt, batch=1, seq=self.sv.max_ctx)
            logits, row = self._prefill(
                self.params, jnp.asarray(tokens), row, jnp.asarray(positions))
            self.caches = scatter_rows(self.caches, row, [req.slot])

        req.n_cached = L
        self.n_prefill_tokens += L
        req.tokens.append(int(self._greedy(logits)[0]))
        if req.t_first is None:
            req.t_first = self.clock()

    def _decode_batch(self, batch: List[Request]) -> None:
        """One decode step over the running set, padded to a bucket."""
        n = len(batch)
        nb = self.sv.decode_bucket(n)
        tok = np.zeros((nb, 1), np.int32)
        pos = np.full((nb, 1), -1, np.int32)
        for i, req in enumerate(batch):
            tok[i, 0] = req.tokens[-1]      # feed the newest generated token
            pos[i, 0] = req.n_cached        # ... at the next cache position

        if self.sv.layout == "paged":
            tbl = np.stack([self.kv.table_row(r.rid) for r in batch]
                           + [np.zeros(self.sv.pages_per_seq, np.int32)]
                           * (nb - n))
            caches = with_block_tables(self.caches, tbl)
            logits, self.caches = self._decode(
                self.params, jnp.asarray(tok), caches, jnp.asarray(pos))
        else:
            rows = [r.slot for r in batch] \
                + [self.sv.max_batch - 1] * (nb - n)   # pads write nothing
            sub = gather_rows(self.caches, rows)
            logits, sub = self._decode(
                self.params, jnp.asarray(tok), sub, jnp.asarray(pos))
            # scatter only the active rows back (a pad row may alias an
            # active slot, and duplicate scatter indices would race)
            self.caches = scatter_rows(
                self.caches, gather_rows(sub, np.arange(n)), rows[:n])
        nxt = self._greedy(logits)
        for i, req in enumerate(batch):
            req.n_cached += 1
            req.tokens.append(int(nxt[i]))
        self.n_decode_tokens += n

    # ------------------------------------------------------------- stats --
    def stats(self) -> Dict:
        done = [r for r in self._all.values() if r.t_finish is not None]
        lat = [r.t_finish - r.t_visible for r in done]
        ttft = [r.t_first - r.t_visible for r in done if r.t_first]
        wall = (self.clock() - self.t_start) if self.t_start else 0.0
        pct = (lambda xs, q: float(np.percentile(xs, q)) if xs else None)
        return {
            "layout": self.sv.layout,
            "requests_finished": len(done),
            "requests_preempted": self.scheduler.n_preemptions,
            "steps": self.n_steps,
            "prefill_tokens": self.n_prefill_tokens,
            "decode_tokens": self.n_decode_tokens,
            "wall_s": wall,
            "decode_tok_per_s": self.n_decode_tokens / wall if wall else None,
            "latency_p50_s": pct(lat, 50),
            "latency_p95_s": pct(lat, 95),
            "ttft_p50_s": pct(ttft, 50),
            "ttft_p95_s": pct(ttft, 95),
            "kv_pages_high_water": getattr(self.kv, "high_water", 0),
        }
