"""Thin serving frontend: submit()/step()/collect() + synthetic traffic.

`poisson_trace` draws a reproducible open-loop request trace — exponential
interarrival times (in decode-step units, so scheduling decisions replay
identically across engines and KV layouts) with prompt/generation lengths
mixed over caller-provided choices.  `run_trace` feeds a trace through an
engine and returns the stats report; serve.py's benchmark and the
bit-exactness harness both sit on top of it.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.engine import InferenceEngine
from repro.serving.scheduler import Request, ShedError


@dataclasses.dataclass(frozen=True)
class TraceItem:
    arrival_step: int          # engine step at which the request arrives
    prompt: np.ndarray         # int32 [L]
    max_new: int


class ServingAPI:
    """submit/step/collect facade over the engine (the unit a network
    frontend would wrap; requests become visible immediately)."""

    def __init__(self, engine: InferenceEngine):
        self.engine = engine

    def submit(self, prompt, max_new: int, eos_id: Optional[int] = None,
               deadline_s: Optional[float] = None) -> int:
        """Queue a request; ``deadline_s`` is a TTL relative to now
        (outcome=timeout once it passes).  Raises ShedError when the
        bounded admission queue is full."""
        return self.engine.submit(prompt, max_new, eos_id=eos_id,
                                  deadline_s=deadline_s)

    def cancel(self, rid: int) -> bool:
        """Cancel a queued, prefilling, or decoding request: pages release
        (shared prefix pages stay warm), the request retires with
        outcome=cancelled and surfaces via collect().  False when rid is
        unknown or already retired."""
        return self.engine.cancel(rid)

    def step(self) -> int:
        return self.engine.step()

    def collect(self) -> List[Request]:
        return self.engine.collect()

    def stats(self) -> Dict:
        return self.engine.stats()

    # ------------------------------------------------------- telemetry --
    @property
    def trace(self):
        """The engine's trace recorder (save()/to_chrome() for Perfetto)."""
        return self.engine.trace

    def metrics_text(self) -> str:
        """Prometheus text exposition of the engine's registry — the body
        a network frontend's /metrics endpoint would serve."""
        return self.engine.metrics.render_text()


def poisson_trace(
    n_requests: int,
    rate_per_step: float,
    prompt_lens: Sequence[int],
    gen_lens: Sequence[int],
    vocab: int,
    seed: int = 0,
) -> List[TraceItem]:
    """Open-loop Poisson arrivals: interarrival ~ Exp(rate) in decode-step
    units; prompt/gen lengths drawn uniformly from the given choices."""
    rng = np.random.default_rng(seed)
    t, out = 0.0, []
    for _ in range(n_requests):
        t += rng.exponential(1.0 / max(rate_per_step, 1e-9))
        L = int(rng.choice(list(prompt_lens)))
        out.append(TraceItem(
            arrival_step=int(t),
            prompt=rng.integers(0, vocab, size=L, dtype=np.int32),
            max_new=int(rng.choice(list(gen_lens))),
        ))
    return out


def shared_prefix_trace(
    n_requests: int,
    rate_per_step: float,
    sys_len: int,
    user_lens: Sequence[int],
    gen_lens: Sequence[int],
    vocab: int,
    seed: int = 0,
    n_system_prompts: int = 1,
) -> List[TraceItem]:
    """Shared-system-prompt traffic: every request's prompt is one of
    `n_system_prompts` fixed system prefixes (`sys_len` tokens) followed by
    a unique user suffix — the workload where a prefix cache amortizes the
    system prompt's KV across the fleet.  Arrivals follow the same
    open-loop Poisson process as `poisson_trace`."""
    rng = np.random.default_rng(seed)
    systems = [rng.integers(0, vocab, size=sys_len, dtype=np.int32)
               for _ in range(max(1, n_system_prompts))]
    t, out = 0.0, []
    for _ in range(n_requests):
        t += rng.exponential(1.0 / max(rate_per_step, 1e-9))
        sys_p = systems[int(rng.integers(0, len(systems)))]
        user = rng.integers(0, vocab, size=int(rng.choice(list(user_lens))),
                            dtype=np.int32)
        out.append(TraceItem(
            arrival_step=int(t),
            prompt=np.concatenate([sys_p, user]),
            max_new=int(rng.choice(list(gen_lens))),
        ))
    return out


def mixed_trace(
    n_requests: int,
    prompt_lens: Sequence[int],
    gen_lens: Sequence[int],
    vocab: int,
    seed: int = 0,
) -> List[TraceItem]:
    """Batch-composition churn: exactly one arrival per decode step with
    prompt/gen lengths cycling through the cross product, so every step's
    running set mixes prefill chunks and decode tokens differently — the
    workload the ragged token-major step exists for (a bucketed engine
    re-pads every step; the ragged engine reuses one compiled shape)."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n_requests):
        L = int(prompt_lens[i % len(prompt_lens)])
        g = int(gen_lens[(i // len(prompt_lens)) % len(gen_lens)])
        prompt = rng.integers(0, vocab, size=L, dtype=np.int32)
        out.append(TraceItem(arrival_step=i, prompt=prompt, max_new=g))
    return out


def bursty_trace(
    n_requests: int,
    burst: int,
    period: int,
    prompt_lens: Sequence[int],
    gen_lens: Sequence[int],
    vocab: int,
    seed: int = 0,
) -> List[TraceItem]:
    """Bursty arrivals: groups of `burst` simultaneous requests every
    `period` decode steps (idle gaps between), alternating long-prompt and
    short-prompt bursts.  Stresses admission spikes — the bucketed engine
    pays one prefill launch per admission, the ragged engine drains the
    whole burst through its token budget."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n_requests):
        group = i // burst
        L = int(prompt_lens[(group + i) % len(prompt_lens)])
        g = int(gen_lens[i % len(gen_lens)])
        prompt = rng.integers(0, vocab, size=L, dtype=np.int32)
        out.append(TraceItem(arrival_step=group * period, prompt=prompt,
                             max_new=g))
    return out


def run_trace(engine: InferenceEngine, trace: List[TraceItem],
              max_steps: int = 100_000) -> Tuple[Dict, List[Request]]:
    """Drive a trace to completion: submit each request at its arrival step,
    step until every request finished.  Returns (stats, finished requests
    sorted by rid)."""
    pending = sorted(trace, key=lambda it: it.arrival_step)
    finished: List[Request] = []
    i, step_idx = 0, 0
    while len(finished) < len(trace):
        if step_idx >= max_steps:
            raise RuntimeError(f"trace incomplete after {max_steps} steps")
        while i < len(pending) and pending[i].arrival_step <= step_idx:
            try:
                engine.submit(pending[i].prompt, pending[i].max_new)
            except ShedError:
                pass     # shed requests still retire through collect()
            i += 1
        engine.step()
        finished.extend(engine.collect())
        step_idx += 1
    return engine.stats(), sorted(finished, key=lambda r: r.rid)
