"""Production mesh builders (functions, not module constants: importing this
module never touches jax device state)."""

from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """The assignment's production mesh: 16x16 per pod (256 chips),
    optionally 2 pods = 512 chips with a leading 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / small fake-device runs)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def single_device_mesh():
    from jax.sharding import Mesh

    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
