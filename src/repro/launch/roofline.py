"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (TPU v5e constants):

    compute    = FLOPs / (PEAK_FLOPS)            per device
    memory     = bytes_accessed / HBM_BW         per device
    collective = collective_operand_bytes / ICI_BW_PER_LINK   per device

Methodology (full derivation in EXPERIMENTS.md):
  * XLA's cost_analysis counts while-loop bodies ONCE, so the production
    (scan-over-layers) compile is used only for memory_analysis;
  * cost/collective terms come from *unrolled probe* lowerings at 2 and 4
    pattern-repeats, linearly extrapolated to the full depth:
        total(R) = c(2) + (c(4) - c(2)) / 2 * (R - 2)
    The probes unroll layers, materialize attention scores and skip loss
    chunking => their HLO contains no loops and every op is counted exactly.
  * collective bytes are parsed from the probe's post-SPMD HLO: for each
    collective op we sum its *operand* sizes (name -> shape map built from
    the whole module), classify by kind, and split intra-pod vs cross-pod
    from replica_groups (pod-major device order).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

# ----------------------------------------------------- hardware constants --
PEAK_FLOPS_BF16 = 197e12        # per chip, TPU v5e
PEAK_FLOPS_INT8 = 394e12        # int8 MXU path (2x bf16)
HBM_BW = 819e9                  # B/s per chip
ICI_BW_PER_LINK = 50e9          # B/s per link (conservative single-link)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?[^=]*?)\s+"
                        r"([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
# iota form: replica_groups=[ngroups,gsize]<=[d0,d1,..]T(p0,p1,..)  (T opt.)
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")


def _iota_groups_cross_pod(m, pod_size: int) -> bool:
    """Decode an iota replica-group spec; True if any group spans pods."""
    import numpy as np

    ngroups, gsize = int(m.group(1)), int(m.group(2))
    dims = [int(x) for x in m.group(3).split(",")]
    ids = np.arange(int(np.prod(dims))).reshape(dims)
    if m.group(4):
        perm = [int(x) for x in m.group(4).split(",")]
        ids = ids.transpose(perm)
    groups = ids.reshape(ngroups, gsize)
    return bool(((groups.max(1) // pod_size) != (groups.min(1) // pod_size)).any())


def _type_bytes(type_str: str) -> float:
    """Bytes of an HLO type string (sums tuple components)."""
    total = 0.0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float]
    cross_pod_bytes: float
    count: int

    def total(self) -> float:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str, pod_size: Optional[int] = None
                      ) -> CollectiveStats:
    """Sum collective operand bytes from post-SPMD HLO text."""
    name_to_bytes: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _OP_DEF_RE.match(line)
        if m:
            name_to_bytes[m.group(1)] = _type_bytes(m.group(2))

    bytes_by_kind = {k: 0.0 for k in _COLLECTIVES}
    cross_pod = 0.0
    count = 0
    for line in hlo_text.splitlines():
        m = _OP_DEF_RE.match(line)
        if not m:
            continue
        opname = m.group(3)
        kind = None
        for k in _COLLECTIVES:
            if opname == k or opname == k + "-start":
                kind = k
                break
        if kind is None:
            continue
        count += 1
        # operands: %refs inside the first (...) after the op name
        call = line[line.index(opname + "("):]
        depth = 0
        arglist = ""
        for ch in call[len(opname):]:
            if ch == "(":
                depth += 1
                if depth == 1:
                    continue
            if ch == ")":
                depth -= 1
                if depth == 0:
                    break
            if depth >= 1:
                arglist += ch
        op_bytes = sum(
            name_to_bytes.get(r, 0.0) for r in _OPERAND_RE.findall(arglist)
        )
        if op_bytes == 0.0:
            # fall back to result bytes (e.g. operands are literals)
            op_bytes = _type_bytes(m.group(2))
        bytes_by_kind[kind] += op_bytes
        if pod_size:
            g = _GROUPS_RE.search(line)
            if g:
                for grp in re.findall(r"\{([^}]*)\}", g.group(1)):
                    ids = [int(x) for x in grp.split(",") if x.strip()]
                    if ids and (max(ids) // pod_size) != (min(ids) // pod_size):
                        cross_pod += op_bytes
                        break
            else:
                gi = _GROUPS_IOTA_RE.search(line)
                if gi and _iota_groups_cross_pod(gi, pod_size):
                    cross_pod += op_bytes
    return CollectiveStats(bytes_by_kind, cross_pod, count)


# ---------------------------------------------------------- model flops ----
def model_params(cfg) -> Tuple[int, int]:
    """(total params N, active-per-token params N_active), analytic."""
    D, L, V = cfg.d_model, cfg.n_layers, cfg.vocab_padded
    def attn_p():
        return D * cfg.n_heads * cfg.hd * 2 + D * cfg.n_kv_heads * cfg.hd * 2

    def ffn_p(F):
        mult = 3 if cfg.ffn_type == "swiglu" else 2
        return mult * D * F

    total = active = 0
    counts = {"A": 0, "M": 0, "R": 0}
    pattern_full = list(cfg.pattern) * cfg.n_repeats + list(cfg.tail)
    for bt in pattern_full:
        counts[bt] += 1
    for bt, n in counts.items():
        if n == 0:
            continue
        if bt == "A":
            per = attn_p()
            per_active = per
            if cfg.n_experts:
                e = ffn_p(cfg.d_ff_expert or cfg.d_ff)
                per += cfg.n_experts * e + D * cfg.n_experts
                per_active += cfg.top_k * e
                if cfg.shared_expert:
                    per += e
                    per_active += e
                if cfg.moe_dense_ff:
                    de = ffn_p(cfg.moe_dense_ff)
                    per += de
                    per_active += de
            elif cfg.d_ff:
                per += ffn_p(cfg.d_ff)
                per_active = per
        elif bt == "M":
            di, N, H, G = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_groups
            per = D * (2 * di + 2 * G * N + H) + di * D
            per_active = per
        else:  # R
            W = cfg.lru_width or D
            per = 2 * D * W + 2 * W * W + W * D
            per_active = per
            if cfg.d_ff:
                per += ffn_p(cfg.d_ff)
                per_active += ffn_p(cfg.d_ff)
        total += per * n
        active += per_active * n
    emb = V * D * (1 if cfg.tie_embeddings else 2)
    total += emb
    active += emb
    return int(total), int(active)


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS for the cell: 6*N*tokens (train, incl. bwd) or
    2*N_active*tokens (inference), plus causal-attention score FLOPs."""
    n_total, n_active = model_params(cfg)
    B, S = shape.batch, shape.seq
    n_attn_layers = sum(
        1 for bt in (list(cfg.pattern) * cfg.n_repeats + list(cfg.tail))
        if bt == "A"
    )
    if shape.kind == "train":
        tokens = B * S
        gemm = 6 * n_active * tokens
        ctx = min(S, cfg.local_window) if cfg.local_window else S
        attn = 3 * 2 * 2 * B * S * ctx / 2 * cfg.n_heads * cfg.hd * n_attn_layers
        return gemm + attn
    if shape.kind == "prefill":
        tokens = B * S
        gemm = 2 * n_active * tokens
        ctx = min(S, cfg.local_window) if cfg.local_window else S
        attn = 2 * 2 * B * S * ctx / 2 * cfg.n_heads * cfg.hd * n_attn_layers
        return gemm + attn
    # decode: one token against a cache of length S
    tokens = B
    gemm = 2 * n_active * tokens
    ctx = min(S, cfg.local_window) if cfg.local_window else S
    attn = 2 * 2 * B * ctx * cfg.n_heads * cfg.hd * n_attn_layers
    return gemm + attn


# ------------------------------------------------------------------ terms --
def roofline_terms(
    flops_per_dev: float,
    bytes_per_dev: float,
    coll_bytes_per_dev: float,
    *,
    int8_fraction: float = 0.0,
) -> Dict[str, float]:
    peak = PEAK_FLOPS_BF16 * (1 - int8_fraction) + PEAK_FLOPS_INT8 * int8_fraction
    t_c = flops_per_dev / peak
    t_m = bytes_per_dev / HBM_BW
    t_x = coll_bytes_per_dev / ICI_BW_PER_LINK
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])[0]
    return {
        "compute_s": t_c,
        "memory_s": t_m,
        "collective_s": t_x,
        "bound": dom,
        "step_s_lower_bound": max(t_c, t_m, t_x),
    }
