"""Batched serving driver: prefill a batch of prompts, then decode with the
paper's packed-int4 weights (or any quant backend), measuring tokens/s.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
        --batch 4 --prompt-len 32 --gen 16 --quant w4a4_packed
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import Runtime, get_config
from repro.core.qlinear import pack_tree
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import init_caches, init_model


def serve(arch: str, *, reduced=True, batch=4, prompt_len=32, gen=16,
          quant_backend="w4a4_packed", cache_dtype="bfloat16", seed=0):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    rt = Runtime(scan_layers=True, attn_impl="chunked",
                 attn_chunk_q=min(512, prompt_len), loss_chunk=0,
                 quant_backend=quant_backend, cache_dtype=cache_dtype,
                 remat="none")
    key = jax.random.PRNGKey(seed)
    params = init_model(key, cfg)
    if quant_backend in ("w4a4_packed", "w4a16_packed"):
        params = pack_tree(params, rt.quant_cfg(cfg))

    total = prompt_len + gen
    caches = init_caches(cfg, rt, batch=batch, seq=total)
    prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab)

    prefill_fn = jax.jit(make_prefill_step(cfg, rt), donate_argnums=(2,))
    decode_fn = jax.jit(make_decode_step(cfg, rt), donate_argnums=(2,))

    t0 = time.time()
    logits, caches = prefill_fn(params, prompts, caches)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits[:, : cfg.vocab], axis=-1)[:, None]
    out_tokens = [np.asarray(tok)]
    t0 = time.time()
    for t in range(gen - 1):
        pos = jnp.full((batch, 1), prompt_len + t, jnp.int32)
        logits, caches = decode_fn(params, tok, caches, pos)
        tok = jnp.argmax(logits[:, : cfg.vocab], axis=-1)[:, None]
        out_tokens.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    seqs = np.concatenate(out_tokens, axis=1)
    return {
        "prefill_s": t_prefill,
        "decode_tok_per_s": batch * (gen - 1) / max(t_decode, 1e-9),
        "generated": seqs[:, :8].tolist(),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--quant", default="w4a4_packed")
    ap.add_argument("--cache-dtype", default="bfloat16")
    args = ap.parse_args()
    out = serve(args.arch, reduced=not args.full, batch=args.batch,
                prompt_len=args.prompt_len, gen=args.gen,
                quant_backend=args.quant, cache_dtype=args.cache_dtype)
    print(json.dumps(out))


if __name__ == "__main__":
    main()
