"""Continuous-batching serving driver over the paper's packed-int4 weights.

Drives the repro.serving engine with synthetic Poisson traffic (mixed
prompt/generation lengths) and prints a JSON report with tokens/s and
p50/p95 per-request latency.  `--layout compare` runs the same trace through
the paged and contiguous KV layouts and verifies the generated tokens are
bit-identical.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
        --layout compare --requests 8 --rate 0.5 --quant w4a4_packed \
        --out BENCH_serve.json
"""

from __future__ import annotations

import argparse
import json

from repro.configs import Runtime, ServingConfig, get_config
from repro.serving.api import poisson_trace, run_trace
from repro.serving.engine import InferenceEngine, build_params


def serve(arch: str, *, reduced=True, layout=None, max_batch=4,
          page_size=16, num_pages=48, max_ctx=128, requests=8, rate=0.5,
          prompt_lens=(8, 16, 32), gen_lens=(8, 16),
          quant_backend="w4a4_packed", cache_dtype="bfloat16", seed=0):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    if layout is None:   # paged needs a pure-attention stack (SSM doesn't page)
        blocks = tuple(cfg.pattern) + tuple(cfg.tail)
        layout = "paged" if all(bt == "A" for bt in blocks) else "contiguous"
    rt = Runtime(scan_layers=True, attn_impl="chunked",
                 attn_chunk_q=min(512, max_ctx), loss_chunk=0,
                 quant_backend=quant_backend, cache_dtype=cache_dtype,
                 remat="none")
    trace = poisson_trace(requests, rate, prompt_lens, gen_lens,
                          cfg.vocab, seed=seed)
    layouts = (["paged", "contiguous"] if layout == "compare" else [layout])
    params = build_params(cfg, rt, seed)

    report = {"arch": arch, "reduced": reduced,
              "quant": quant_backend, "cache_dtype": cache_dtype,
              "requests": requests, "rate_per_step": rate}
    tokens_by_layout = {}
    for lay in layouts:
        sv = ServingConfig(layout=lay, max_batch=max_batch,
                           page_size=page_size, num_pages=num_pages,
                           max_ctx=max_ctx)
        engine = InferenceEngine(cfg, rt, sv, params=params)
        engine.warmup(prompt_lens)     # compiles excluded from the stats
        stats, finished = run_trace(engine, trace)
        report[lay] = stats
        tokens_by_layout[lay] = [r.tokens for r in finished]

    if layout == "compare":
        same = tokens_by_layout["paged"] == tokens_by_layout["contiguous"]
        report["bit_identical"] = bool(same)
        if not same:
            # only the paged layout preempts; with a lossy KV dtype the
            # recompute-resume re-attends in full precision, so argmax can
            # legitimately diverge (EXPERIMENTS.md §Serving)
            if (cache_dtype in ("int8", "int4")
                    and report["paged"]["requests_preempted"] > 0):
                report["note"] = ("paged diverged after preemption with a "
                                  "lossy KV-cache dtype: recomputed prefixes "
                                  "attend in full precision — expected")
            else:
                raise SystemExit(
                    "FAIL: paged and contiguous decode diverged")
    # headline numbers from the primary layout
    primary = report[layouts[0]]
    report["tokens_per_s"] = primary["decode_tok_per_s"]
    report["latency_p50_s"] = primary["latency_p50_s"]
    report["latency_p95_s"] = primary["latency_p95_s"]
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    grp = ap.add_mutually_exclusive_group()
    grp.add_argument("--reduced", action="store_true", default=True)
    grp.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--layout", default=None,
                    choices=["paged", "contiguous", "compare"],
                    help="default: paged for attention archs, else contiguous")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=48)
    ap.add_argument("--max-ctx", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--rate", type=float, default=0.5,
                    help="Poisson arrival rate in requests per decode step")
    ap.add_argument("--prompt-lens", default="8,16,32")
    ap.add_argument("--gen-lens", default="8,16")
    ap.add_argument("--quant", default="w4a4_packed")
    ap.add_argument("--cache-dtype", default="bfloat16")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="also write the JSON report to this path")
    args = ap.parse_args()

    out = serve(
        args.arch, reduced=args.reduced, layout=args.layout,
        max_batch=args.max_batch, page_size=args.page_size,
        num_pages=args.num_pages, max_ctx=args.max_ctx,
        requests=args.requests, rate=args.rate,
        prompt_lens=tuple(int(x) for x in args.prompt_lens.split(",")),
        gen_lens=tuple(int(x) for x in args.gen_lens.split(",")),
        quant_backend=args.quant, cache_dtype=args.cache_dtype,
        seed=args.seed,
    )
    text = json.dumps(out, indent=1)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")


if __name__ == "__main__":
    main()
