"""Continuous-batching serving driver over the paper's packed-int4 weights.

Drives the repro.serving engine with synthetic Poisson traffic (mixed
prompt/generation lengths) and prints a JSON report with tokens/s and
p50/p95 per-request latency.  `--layout compare` runs the same trace through
three attention paths — contiguous KV, paged KV with the gather
(`paged_read`-then-attend) baseline, and paged KV with the fused
paged-attention kernel — and verifies the generated tokens are
bit-identical across all three; with the prefix cache on it adds a fourth
`paged_nocache` cold twin, proving cache-hit runs token-identical to cold
runs, and always a fifth `ragged` path: the token-major engine that packs
mixed prefill chunks + decode tokens into one fused launch per step
(`--step ragged` selects it for single-layout runs).  `--scenario
shared_prefix` swaps the traffic for a shared-system-prompt fleet (the
prefix cache's target workload) and the report carries `prefix_hit_rate` /
`tokens_prefilled_saved`; `mixed` churns batch composition every step and
`bursty` groups arrivals — the ragged step's stress workloads.

Mixed precision: `--quant-plan <name|path|inline>` serves under any
site-addressable QuantPlan (core.quant_plan).  `--quantized-ckpt` proves the
quantized-checkpoint path end-to-end: save packed nibbles + scales + plan,
restore with no float master, serve from the restored tree, and verify
bit-identical logits/tokens against the same plan applied to float masters.
`--sweep` adds the per-site sensitivity table to the report.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
        --layout compare --requests 8 --rate 0.5 --quant w4a4_packed \
        --out BENCH_serve.json
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
        --layers 2 --quant-plan mixed_sensitive --quantized-ckpt --sweep \
        --out BENCH_quantized.json
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import Runtime, ServingConfig, get_config
from repro.observability import Telemetry, global_registry
from repro.serving.api import (
    bursty_trace,
    mixed_trace,
    poisson_trace,
    run_trace,
    shared_prefix_trace,
)
from repro.serving.engine import InferenceEngine, build_params


def _dir_bytes(path: str) -> int:
    total = 0
    for root, _, files in os.walk(path):
        total += sum(os.path.getsize(os.path.join(root, f)) for f in files)
    return total


def _quantized_ckpt_report(cfg, rt, ckpt_dir, seed):
    """Save a quantized checkpoint from fresh float masters, restore it, and
    verify it against the same plan applied directly to the masters.
    Returns (serving_params_from_ckpt, report_dict)."""
    from repro.checkpoint import save_checkpoint, save_quantized, \
        restore_quantized
    from repro.core.quant_plan import (
        CKPT_PACKED, active_plan, plan_pack_tree,
    )
    from repro.kernels import ops
    from repro.core.qlinear import prepack_tree
    from repro.models import forward, init_model

    masters = init_model(jax.random.PRNGKey(seed), cfg)
    plan = active_plan(cfg, rt)

    t0 = time.perf_counter()
    save_quantized(os.path.join(ckpt_dir, "q"), 0, masters, cfg, plan=plan)
    save_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    restored, manifest = restore_quantized(os.path.join(ckpt_dir, "q"),
                                           cfg=cfg, rt=rt)
    load_s = time.perf_counter() - t0
    # float-master baseline checkpoint, for the size/load-time comparison
    t0 = time.perf_counter()
    save_checkpoint(os.path.join(ckpt_dir, "f"), 0, masters)
    float_save_s = time.perf_counter() - t0

    # the float-master path: the same plan packed at load time
    reference = plan_pack_tree(masters, cfg, plan, backends=CKPT_PACKED,
                               scale_dtype=jnp.bfloat16)
    tokens = jax.random.randint(jax.random.PRNGKey(seed + 1), (1, 8),
                                0, cfg.vocab, dtype=jnp.int32)
    la = np.asarray(forward(restored, tokens, cfg, rt)[0], np.float32)
    lb = np.asarray(forward(reference, tokens, cfg, rt)[0], np.float32)
    report = {
        "plan": plan.name or "inline",
        "manifest_format": manifest.get("format"),
        "bit_identical_logits": bool(np.array_equal(la, lb)),
        "quantized_bytes": _dir_bytes(os.path.join(ckpt_dir, "q")),
        "float_master_bytes": _dir_bytes(os.path.join(ckpt_dir, "f")),
        "save_s": round(save_s, 3),
        "load_s": round(load_s, 3),
        "float_save_s": round(float_save_s, 3),
    }
    if ops.use_pallas():
        restored = prepack_tree(restored)
        reference = prepack_tree(reference)
    return restored, reference, report


def serve(arch: str, *, reduced=True, layers=None, layout=None, max_batch=4,
          page_size=16, num_pages=48, max_ctx=128, requests=8, rate=0.5,
          prompt_lens=(8, 16, 32), gen_lens=(8, 16), scenario="poisson",
          sys_len=32, prefix_cache=True, step="bucketed", token_budget=0,
          burst=4, period=8,
          quant_backend="w4a4_packed", quant_plan=None, cache_dtype="bfloat16",
          quantized_ckpt=False, ckpt_dir=None, sweep=False, seed=0,
          chaos_seed=0, max_queue=0,
          trace_out=None, metrics=True):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced(**({"n_layers": layers} if layers else {}))

    if scenario in ("chaos", "cancel_storm"):
        # deterministic fault-injection harness (serving.chaos): seeded
        # cancel/deadline storms + allocator failures + step exceptions +
        # mid-run stop/resume, run in BOTH step modes against one fault-
        # free reference.  Exact-softmax prefill ("chunked") so the
        # ragged-vs-bucketed survivor-identity assertion compares
        # identical math (same reason compare mode uses it).
        from repro.serving.chaos import (
            CANCEL_STORM, ChaosConfig, chaos_report,
        )
        rt = Runtime(scan_layers=True, attn_impl="chunked",
                     attn_chunk_q=min(512, max_ctx), loss_chunk=0,
                     quant_backend=None if quant_plan else quant_backend,
                     quant_plan=quant_plan, cache_dtype=cache_dtype,
                     remat="none")
        base = CANCEL_STORM if scenario == "cancel_storm" else ChaosConfig()
        chaos = dataclasses.replace(
            base, seed=chaos_seed, n_requests=requests, rate_per_step=rate,
            prompt_lens=tuple(prompt_lens), gen_lens=tuple(gen_lens),
            stop_resume_at=(max(2, requests // 2),))
        # chaos runs always bound the admission queue so load shedding is
        # exercised (still deterministic: queue depth at submit time is a
        # pure function of the seed)
        sv = ServingConfig(layout="paged", max_batch=max_batch,
                           page_size=page_size, num_pages=num_pages,
                           max_ctx=max_ctx, prefix_cache=prefix_cache,
                           token_budget=token_budget,
                           max_queue=max_queue or 2 * max_batch)
        return {"arch": arch, "reduced": reduced, "scenario": scenario,
                "quant": quant_plan or quant_backend,
                "cache_dtype": cache_dtype,
                **chaos_report(cfg, rt, sv, chaos)}
    if layout is None:   # paged needs a pure-attention stack (SSM doesn't page)
        blocks = tuple(cfg.pattern) + tuple(cfg.tail)
        layout = "paged" if all(bt == "A" for bt in blocks) else "contiguous"
    # perf runs prefill through the flash kernel; the compare harness uses
    # exact-softmax prefill ("chunked") so the token-identity assertion
    # compares identical math — flash's online-softmax rescaling rounds
    # differently from the ragged step's page-grouped exact softmax, and on
    # a random-init model that can flip an argmax tie in the prompt logits
    rt = Runtime(scan_layers=True,
                 attn_impl="chunked" if layout == "compare" else "flash",
                 attn_chunk_q=min(512, max_ctx), loss_chunk=0,
                 quant_backend=None if quant_plan else quant_backend,
                 quant_plan=quant_plan, cache_dtype=cache_dtype,
                 remat="none")
    if scenario == "shared_prefix":
        trace = shared_prefix_trace(requests, rate, sys_len, prompt_lens,
                                    gen_lens, cfg.vocab, seed=seed)
        # warm both the cold full prompts (sys + user suffix) and the tail
        # buckets a prefix hit leaves behind, so no engine absorbs a
        # mid-window jit compile
        warm_lens = tuple(prompt_lens) + tuple(sys_len + p
                                               for p in prompt_lens)
    elif scenario == "mixed":
        # one arrival per step, lengths cycling: batch composition changes
        # every step — the ragged step's target workload
        trace = mixed_trace(requests, prompt_lens, gen_lens, cfg.vocab,
                            seed=seed)
        warm_lens = tuple(prompt_lens)
    elif scenario == "bursty":
        trace = bursty_trace(requests, burst, period, prompt_lens, gen_lens,
                             cfg.vocab, seed=seed)
        warm_lens = tuple(prompt_lens)
    else:
        trace = poisson_trace(requests, rate, prompt_lens, gen_lens,
                              cfg.vocab, seed=seed)
        warm_lens = tuple(prompt_lens)
    # "paged" serves through the fused paged-attention kernel;
    # "paged_gather" is the same layout through the paged_read baseline.
    # In compare mode with the prefix cache on, "paged_nocache" adds the
    # cold twin: the same fused path with prefix_cache=off, which must be
    # token-identical to the cache-hit runs (contiguous is a second cold
    # reference — it never prefix-caches).
    # compare mode always includes the ragged token-major engine as a fifth
    # path: same trace, same paged pool, one fused launch per step — its
    # tokens must match every bucketed path
    layouts = (["paged", "paged_gather", "contiguous"]
               + (["paged_nocache"] if prefix_cache else []) + ["ragged"]
               if layout == "compare" else [layout])

    report = {"arch": arch, "reduced": reduced,
              "quant": quant_plan or quant_backend, "cache_dtype": cache_dtype,
              "requests": requests, "rate_per_step": rate,
              "scenario": scenario, "prefix_cache": bool(prefix_cache),
              **({"sys_len": sys_len} if scenario == "shared_prefix" else {})}
    params_ref = None
    if quantized_ckpt:
        # serve from a quantized checkpoint; keep the plan-on-masters twin
        # around to verify the generated tokens match end-to-end
        def with_dir(d):
            return _quantized_ckpt_report(cfg, rt, d, seed)

        if ckpt_dir:
            os.makedirs(ckpt_dir, exist_ok=True)
            params, params_ref, report["quantized_ckpt"] = with_dir(ckpt_dir)
        else:
            with tempfile.TemporaryDirectory() as d:
                params, params_ref, report["quantized_ckpt"] = with_dir(d)
    else:
        params = build_params(cfg, rt, seed)

    tokens_by_layout = {}
    for lay in layouts:
        kv_layout = "contiguous" if lay == "contiguous" else "paged"
        rt_lay = (dataclasses.replace(rt, paged_attn="gather")
                  if lay == "paged_gather" else rt)
        step_mode = ("ragged" if lay == "ragged"
                     else step if layout != "compare"
                     and kv_layout == "paged" else "bucketed")
        sv = ServingConfig(layout=kv_layout, max_batch=max_batch,
                           page_size=page_size, num_pages=num_pages,
                           max_ctx=max_ctx, step=step_mode,
                           token_budget=token_budget,
                           prefix_cache=(prefix_cache
                                         and lay != "paged_nocache"))
        # per-engine telemetry (compare-mode engines keep separate
        # registries); the Perfetto timeline records the primary layout
        tm = Telemetry(metrics=metrics,
                       trace=bool(trace_out) and lay == layouts[0])
        engine = InferenceEngine(cfg, rt_lay, sv, params=params,
                                 telemetry=tm)
        engine.warmup(warm_lens)       # compiles excluded from the stats
        stats, finished = run_trace(engine, trace)
        stats["profile"] = engine.profile()   # attn vs GEMM attribution
        stats["profile_at_step"] = stats["profile"].get("at_step")
        report[lay] = stats
        tokens_by_layout[lay] = [r.tokens for r in finished]
        if tm.trace.enabled:
            tm.trace.save(trace_out)
            report["trace_out"] = trace_out

    if params_ref is not None:
        # end-to-end: the restored-checkpoint engine must generate exactly
        # the tokens of the plan-applied-to-float-masters engine
        sv = ServingConfig(layout=layouts[0], max_batch=max_batch,
                           page_size=page_size, num_pages=num_pages,
                           max_ctx=max_ctx)
        engine_ref = InferenceEngine(cfg, rt, sv, params=params_ref)
        engine_ref.warmup(warm_lens)
        _, finished_ref = run_trace(engine_ref, trace)
        report["quantized_ckpt"]["tokens_match"] = bool(
            tokens_by_layout[layouts[0]] == [r.tokens for r in finished_ref])

    if sweep:
        from repro.launch.sensitivity import sensitivity_sweep

        report["sensitivity"] = sensitivity_sweep(cfg, seed=seed)

    if layout == "compare":
        ref_tokens = tokens_by_layout[layouts[0]]
        same = all(tokens_by_layout[lay] == ref_tokens for lay in layouts[1:])
        report["bit_identical"] = bool(same)
        if not same:
            # only the paged layouts preempt, and only they take prefix-
            # cache hits; with a lossy KV dtype recompute-resume (and a hit
            # prefill) attends dequantized state where the cold path attends
            # full precision, so argmax can legitimately diverge
            # (EXPERIMENTS.md §Serving / §Prefix caching)
            diverged = [lay for lay in layouts[1:]
                        if tokens_by_layout[lay] != ref_tokens]
            lossy_paths = (report["paged"]["requests_preempted"] > 0
                           or report["paged"]["tokens_prefilled_saved"] > 0
                           # ragged chunked prefill always attends the
                           # (dequantized) page pool, where bucketed fresh
                           # prefill attends in-flight full-precision K/V
                           or "ragged" in diverged)
            if cache_dtype in ("int8", "int4") and lossy_paths:
                report["note"] = ("paged/ragged diverged after preemption, a "
                                  "prefix-cache hit, or a chunked prefill "
                                  "with a lossy KV-cache dtype: the other "
                                  "path attends those prefixes in full "
                                  "precision — expected")
            else:
                raise SystemExit(
                    f"FAIL: decode diverged across attention paths "
                    f"({layouts[0]} vs {diverged})")
    # headline numbers from the primary layout
    primary = report[layouts[0]]
    report["tokens_per_s"] = primary["decode_tok_per_s"]
    report["latency_p50_s"] = primary["latency_p50_s"]
    report["latency_p95_s"] = primary["latency_p95_s"]
    report["prefix_hit_rate"] = primary.get("prefix_hit_rate", 0.0)
    report["tokens_prefilled_saved"] = primary.get("tokens_prefilled_saved", 0)
    report["padding_tokens_wasted"] = primary.get("padding_tokens_wasted", 0)
    report["token_utilization"] = primary.get("token_utilization")
    # telemetry headlines: steady-state recompiles (should be 0 — see
    # observability.jit_watch) and the process-wide kernel dispatch mix.
    # Compare mode takes the MAX over every engine, so a single path
    # recompiling mid-window fails the zero-steady-state gate.
    if layout == "compare":
        report["recompiles_steady_state"] = max(
            report[lay].get("recompiles", {}).get("steady_state", 0)
            for lay in layouts)
    else:
        report["recompiles_steady_state"] = (
            primary.get("recompiles", {}).get("steady_state", 0))
    report["kernel_dispatch"] = (
        global_registry().snapshot()["counters"])
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    grp = ap.add_mutually_exclusive_group()
    grp.add_argument("--reduced", action="store_true", default=True)
    grp.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--layers", type=int, default=None,
                    help="override layer count of the reduced config (e.g. 2 "
                         "so block-indexed plan rules have layers to differ on)")
    ap.add_argument("--layout", default=None,
                    choices=["paged", "contiguous", "compare"],
                    help="default: paged for attention archs, else contiguous")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=48)
    ap.add_argument("--max-ctx", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--rate", type=float, default=0.5,
                    help="Poisson arrival rate in requests per decode step")
    ap.add_argument("--prompt-lens", default="8,16,32")
    ap.add_argument("--gen-lens", default="8,16")
    ap.add_argument("--scenario", default="poisson",
                    choices=["poisson", "shared_prefix", "mixed", "bursty",
                             "chaos", "cancel_storm"],
                    help="shared_prefix: every prompt = one shared system "
                         "prefix (--sys-len) + a unique user suffix drawn "
                         "from --prompt-lens; mixed: one arrival per step "
                         "with cycling lengths (batch composition changes "
                         "every step); bursty: --burst arrivals every "
                         "--period steps; chaos: seeded fault-injection "
                         "harness (cancels, deadlines, allocator failures, "
                         "step exceptions, stop/resume) with survivor "
                         "token-identity vs a fault-free run; cancel_storm: "
                         "chaos preset with only a high-rate cancel storm")
    ap.add_argument("--sys-len", type=int, default=32,
                    help="shared system-prompt length (shared_prefix)")
    ap.add_argument("--step", default="bucketed",
                    choices=["bucketed", "ragged"],
                    help="serving step: classic bucketed prefill/decode "
                         "jits, or the ragged token-major single launch "
                         "(paged layout; compare mode always adds a ragged "
                         "path)")
    ap.add_argument("--token-budget", type=int, default=0,
                    help="ragged step's padded token capacity per step "
                         "(0 = auto from max_batch/page_size)")
    ap.add_argument("--burst", type=int, default=4,
                    help="arrivals per burst (bursty scenario)")
    ap.add_argument("--period", type=int, default=8,
                    help="steps between bursts (bursty scenario)")
    ap.add_argument("--prefix-cache", default="on", choices=["on", "off"],
                    help="shared-prefix KV page reuse (paged layout); "
                         "compare mode adds a paged_nocache cold twin "
                         "when on")
    ap.add_argument("--quant", default="w4a4_packed",
                    help="uniform backend (deprecated in favor of "
                         "--quant-plan; kept working via a uniform plan)")
    ap.add_argument("--quant-plan", default=None,
                    help="mixed-precision plan: preset name | json path | "
                         "inline pattern=backend rules (core.quant_plan)")
    ap.add_argument("--cache-dtype", default="bfloat16")
    ap.add_argument("--quantized-ckpt", action="store_true",
                    help="serve from a quantized checkpoint (save+restore, "
                         "verify bit-identical vs plan-on-float-masters)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="keep the quantized checkpoint here (default: tmp)")
    ap.add_argument("--sweep", action="store_true",
                    help="add the per-site sensitivity table to the report")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="seed for the chaos scenarios' trace + fault "
                         "stream (independent of --seed, which picks the "
                         "model weights)")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bounded admission queue: submissions past this "
                         "many waiting requests shed with a typed error "
                         "(0 = unbounded; chaos scenarios default to "
                         "2*max_batch)")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome/Perfetto trace_event JSON timeline "
                         "of the primary layout's run (open at "
                         "ui.perfetto.dev)")
    ap.add_argument("--metrics", default="on", choices=["on", "off"],
                    help="per-engine telemetry registries (off: stats() "
                         "reports empty metrics/recompiles)")
    ap.add_argument("--out", default=None,
                    help="also write the JSON report to this path")
    args = ap.parse_args()

    out = serve(
        args.arch, reduced=args.reduced, layers=args.layers,
        layout=args.layout,
        max_batch=args.max_batch, page_size=args.page_size,
        num_pages=args.num_pages, max_ctx=args.max_ctx,
        requests=args.requests, rate=args.rate,
        prompt_lens=tuple(int(x) for x in args.prompt_lens.split(",")),
        gen_lens=tuple(int(x) for x in args.gen_lens.split(",")),
        scenario=args.scenario, sys_len=args.sys_len,
        prefix_cache=args.prefix_cache == "on",
        step=args.step, token_budget=args.token_budget,
        burst=args.burst, period=args.period,
        quant_backend=args.quant, quant_plan=args.quant_plan,
        cache_dtype=args.cache_dtype,
        quantized_ckpt=args.quantized_ckpt, ckpt_dir=args.ckpt_dir,
        sweep=args.sweep, seed=args.seed,
        chaos_seed=args.chaos_seed, max_queue=args.max_queue,
        trace_out=args.trace_out, metrics=args.metrics == "on",
    )
    text = json.dumps(out, indent=1)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")


if __name__ == "__main__":
    main()
