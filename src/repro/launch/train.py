"""End-to-end trainer with fault tolerance.

Runs the same `make_train_step` the dry-run lowers, over the deterministic
synthetic pipeline, with: atomic checkpoint/resume, per-step watchdog
(straggler/hang detection), bounded retry, optional mesh (single device on
CPU; DP x TP on real slices / fake devices).

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --reduced --steps 300 --batch 8 --seq 128 --ckpt /tmp/run1
"""

from __future__ import annotations

import argparse
import json
import logging
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import Runtime, get_config
from repro.data import SyntheticLMDataset, make_batch_iterator
from repro.distributed.fault_tolerance import StepTimer, Watchdog, run_with_retries
from repro.distributed.sharding import mesh_context
from repro.launch.mesh import make_mesh
from repro.launch.steps import init_train_state, make_train_step

log = logging.getLogger("repro.train")


def train(
    arch: str,
    *,
    steps: int = 100,
    batch: int = 8,
    seq: int = 128,
    reduced: bool = True,
    ckpt_dir: str = "/tmp/repro_ckpt",
    save_every: int = 50,
    mesh_spec: str = "",
    peak_lr: float = 3e-4,
    quant_backend: str = None,
    step_deadline_s: float = 600.0,
    log_every: int = 10,
    seed: int = 0,
):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    rt = Runtime(scan_layers=True, attn_impl="chunked",
                 attn_chunk_q=min(512, seq), loss_chunk=0,
                 quant_backend=quant_backend)
    mesh = None
    if mesh_spec:
        dims = tuple(int(x) for x in mesh_spec.split(","))
        mesh = make_mesh(dims, ("data", "model")[:len(dims)] if len(dims) == 2
                         else ("pod", "data", "model"))

    ds = SyntheticLMDataset(vocab=cfg.vocab, seq_len=seq, global_batch=batch,
                            seed=seed)
    mgr = CheckpointManager(ckpt_dir, save_every=save_every, keep=3)

    with mesh_context(mesh):
        state = init_train_state(jax.random.PRNGKey(seed), cfg)
        start_step = 0
        latest = mgr.latest()
        if latest is not None:
            state, start_step = mgr.restore(state)
            log.info("resumed from step %d", start_step)
        if mesh is not None:
            from repro.distributed.sharding import (
                make_param_shardings, specs_to_shardings)
            pspec = make_param_shardings(state["params"], mesh)
            state = {
                "params": jax.device_put(
                    state["params"], specs_to_shardings(pspec, mesh)),
                "opt": state["opt"],
                "step": state["step"],
            }

        step_fn = jax.jit(make_train_step(cfg, rt, peak_lr=peak_lr,
                                          total_steps=max(steps, 1)),
                          donate_argnums=(0,))
        it = make_batch_iterator(ds, start_step=start_step)
        timer = StepTimer()
        history = []
        wd = Watchdog(deadline_s=step_deadline_s)
        for step in range(start_step, steps):
            batch_np = next(it)

            def one_step():
                with wd:
                    return step_fn(state, jnp.asarray(batch_np))

            timer.start()
            state, metrics = run_with_retries(one_step, max_retries=2)
            dt = timer.stop()
            loss = float(metrics["loss"])
            history.append(loss)
            if step % log_every == 0 or step == steps - 1:
                log.info("step %5d loss %.4f gnorm %.3f lr %.2e %.0f ms",
                         step, loss, float(metrics["grad_norm"]),
                         float(metrics["lr"]), dt * 1e3)
            mgr.maybe_save(step + 1, state)
        it.close()
        mgr.maybe_save(steps, state, force=True)
    return state, history


def main():
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="full (non-reduced) config — real-hardware scale")
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--mesh", default="", help="e.g. '2,4' (data,model)")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--quant", default=None,
                    help="override quant backend (float|fake_quant|int_sim)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    _, history = train(
        args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
        reduced=not args.full, ckpt_dir=args.ckpt, save_every=args.save_every,
        mesh_spec=args.mesh, peak_lr=args.lr, quant_backend=args.quant,
        seed=args.seed,
    )
    print(json.dumps({"first_loss": history[0], "last_loss": history[-1],
                      "steps": len(history)}))


if __name__ == "__main__":
    main()
