"""Multi-pod dry-run: prove the distribution config is coherent without
hardware.  For every (architecture x input shape x mesh) cell this lowers and
compiles the real step function against ShapeDtypeStruct inputs on the
production mesh (single-pod 16x16 = 256 chips; multi-pod 2x16x16 = 512),
prints memory/cost analyses, parses collective traffic from the post-SPMD
HLO, and writes a JSON report consumed by EXPERIMENTS.md §Dry-run/§Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out reports/dryrun]
  python -m repro.launch.dryrun --arch ... --devices 8 --mesh 2,4   (tests)
"""

# The first two executable lines MUST set XLA_FLAGS before any jax import:
# jax locks the device count on first initialization.
import os
import sys

_DEV = "512"
if "--devices" in sys.argv:
    _DEV = sys.argv[sys.argv.index("--devices") + 1]
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={_DEV} "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from typing import Dict, Optional  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import (  # noqa: E402
    REGISTRY, Runtime, SHAPES, get_config, runnable,
)
from repro.core.quant_plan import pack_for_serving  # noqa: E402
from repro.distributed.sharding import (  # noqa: E402
    make_param_shardings, mesh_context, specs_to_shardings,
)
from repro.launch import roofline as rl  # noqa: E402
from repro.launch.mesh import make_mesh, make_production_mesh  # noqa: E402
from repro.launch.steps import (  # noqa: E402
    init_train_state, input_specs, make_decode_step, make_prefill_step,
    make_train_step, state_specs,
)
from repro.models import init_model  # noqa: E402


def production_runtime(shape_kind: str, serve_packed: bool = True,
                       **overrides) -> Runtime:
    """Production execution knobs per step kind (§Perf baselines)."""
    base = dict(scan_layers=True, attn_impl="chunked", attn_chunk_q=512,
                loss_chunk=4096, remat="dots")
    if shape_kind == "train":
        base.update(quant_backend="fake_quant")
    else:
        # serving: pre-packed int4 weights + int4 KV cache (the paper's
        # 4-bit format applied to both weight and cache traffic)
        base.update(quant_backend="w4a4_packed" if serve_packed else "float",
                    cache_dtype="int4" if serve_packed else "bfloat16",
                    remat="none")
    base.update(overrides)
    return Runtime(**base)


def probe_runtime(rt: Runtime) -> Runtime:
    """Loop-free cost-probe variant: unrolled layers, materialized attention,
    unchunked loss (HLO contains every FLOP exactly once)."""
    return dataclasses.replace(rt, scan_layers=False, attn_impl="full",
                               loss_chunk=0, remat="none")


def _serve_params_sds(cfg, rt: Runtime, mesh):
    """ShapeDtypeStruct tree (+shardings) for serving params, packed per the
    active QuantPlan (legacy uniform backends map to uniform plans)."""
    def build():
        p = init_model(jax.random.PRNGKey(0), cfg)
        return pack_for_serving(p, cfg, rt)

    sds = jax.eval_shape(build)
    specs = make_param_shardings(sds, mesh)
    shardings = specs_to_shardings(specs, mesh)
    sds = jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        sds, shardings)
    return sds, shardings


def lower_cell(
    arch: str,
    shape_name: str,
    mesh,
    *,
    repeats_override: Optional[int] = None,
    probe: bool = False,
    rt_overrides: Optional[Dict] = None,
    serve_packed: bool = True,
):
    """Lower+compile one cell; returns (compiled, lowered, cfg, rt)."""
    cfg = get_config(arch)
    if repeats_override is not None:
        cfg = dataclasses.replace(
            cfg,
            n_layers=repeats_override * len(cfg.pattern) + len(cfg.tail),
        )
    shape = SHAPES[shape_name]
    rt = production_runtime(shape.kind, serve_packed=serve_packed,
                            **(rt_overrides or {}))
    if probe:
        rt = probe_runtime(rt)

    with mesh_context(mesh):
        specs = input_specs(cfg, shape, mesh, rt)
        if shape.kind == "train":
            state_sds, state_shard = state_specs(cfg, mesh)
            fn = make_train_step(cfg, rt)
            lowered = jax.jit(fn, donate_argnums=(0,)).lower(
                state_sds, specs["batch"])
        elif shape.kind == "prefill":
            params_sds, _ = _serve_params_sds(cfg, rt, mesh)
            fn = make_prefill_step(cfg, rt)
            lowered = jax.jit(fn, donate_argnums=(2,)).lower(
                params_sds, specs["tokens"], specs["caches"])
        else:
            params_sds, _ = _serve_params_sds(cfg, rt, mesh)
            fn = make_decode_step(cfg, rt)
            lowered = jax.jit(fn, donate_argnums=(2,)).lower(
                params_sds, specs["token"], specs["caches"],
                specs["positions"])
        compiled = lowered.compile()
    return compiled, lowered, cfg, rt


def _mem_fields(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    out = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(ma, f, None)
        if v is not None:
            out[f] = int(v)
    out["total_hbm_bytes"] = (
        out.get("argument_size_in_bytes", 0)
        + out.get("output_size_in_bytes", 0)
        + out.get("temp_size_in_bytes", 0)
        - out.get("alias_size_in_bytes", 0)
    )
    return out


def _cost_fields(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):     # jax 0.4.x: one dict per device set
        ca = ca[0] if ca else {}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0))}


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, mesh=None,
             probes=(2, 4), rt_overrides=None, serve_packed=True,
             skip_probes=False) -> Dict:
    t0 = time.time()
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    pod_size = n_dev // mesh.shape.get("pod", 1)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    report: Dict = {
        "arch": arch, "shape": shape_name,
        "mesh": dict(mesh.shape), "devices": n_dev,
        "multi_pod": multi_pod,
    }
    if not runnable(cfg, shape):
        report["status"] = "skipped"
        report["reason"] = ("long_500k requires sub-quadratic attention; "
                            f"{arch} is full-attention (DESIGN.md §4)")
        return report

    # ---- 1. production compile (scan-over-layers): memory analysis --------
    compiled, lowered, cfg_full, rt = lower_cell(
        arch, shape_name, mesh, rt_overrides=rt_overrides,
        serve_packed=serve_packed)
    report["memory"] = _mem_fields(compiled)
    report["cost_scanned_body_once"] = _cost_fields(compiled)
    report["status"] = "ok"

    # ---- 2. cost probes (unrolled, loop-free), linear extrapolation -------
    if not skip_probes:
        probe_data = {}
        for r in probes:
            c_p, l_p, _, _ = lower_cell(
                arch, shape_name, mesh, repeats_override=r, probe=True,
                rt_overrides=rt_overrides, serve_packed=serve_packed)
            cf = _cost_fields(c_p)
            coll = rl.parse_collectives(c_p.as_text(), pod_size=pod_size)
            probe_data[r] = {
                **cf,
                "collective_bytes": coll.total(),
                "collective_by_kind": coll.bytes_by_kind,
                "cross_pod_bytes": coll.cross_pod_bytes,
                "collective_count": coll.count,
            }
        report["probes"] = probe_data
        r_lo, r_hi = min(probes), max(probes)
        R = cfg.n_repeats
        scale = (R - r_lo) / (r_hi - r_lo)

        def extrap(field):
            lo, hi = probe_data[r_lo][field], probe_data[r_hi][field]
            return lo + (hi - lo) * scale

        flops = extrap("flops")
        bytes_acc = extrap("bytes_accessed")
        coll_bytes = extrap("collective_bytes")
        cross_pod = extrap("cross_pod_bytes")

        # ---- 3. roofline terms --------------------------------------------
        mf = rl.model_flops(cfg, shape)
        terms = rl.roofline_terms(flops, bytes_acc, coll_bytes)
        report["roofline"] = {
            **terms,
            "flops_per_dev": flops,
            "bytes_per_dev": bytes_acc,
            "collective_bytes_per_dev": coll_bytes,
            "cross_pod_bytes_per_dev": cross_pod,
            "model_flops_global": mf,
            "model_flops_per_dev": mf / n_dev,
            "useful_flop_ratio": (mf / n_dev) / flops if flops else None,
        }
    report["elapsed_s"] = round(time.time() - t0, 1)
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--devices", type=str, default="512")  # parsed pre-import
    ap.add_argument("--mesh", type=str, default=None,
                    help="override mesh, e.g. '2,4' => data=2, model=4")
    ap.add_argument("--out", type=str, default="reports/dryrun")
    ap.add_argument("--skip-probes", action="store_true")
    ap.add_argument("--serve-float", action="store_true",
                    help="serving cells use bf16 weights (baseline)")
    ap.add_argument("--quant-plan", default=None,
                    help="mixed-precision plan for serving cells: preset "
                         "name | json path | inline pattern=backend rules "
                         "(see core.quant_plan) — cost-model any plan")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = []
    archs = sorted(REGISTRY) if (args.all or args.arch is None) else [args.arch]
    shapes = (sorted(SHAPES) if (args.all or args.shape is None)
              else [args.shape])
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    custom_mesh = None
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split(","))
        axes = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
        custom_mesh = make_mesh(dims, axes)
        meshes = [len(dims) == 3]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                key = f"{arch}__{shape}__{'pod2' if mp else 'pod1'}"
                # the plan override models *serving* deployments; train
                # cells keep their QAT runtime (fake_quant)
                serve_cell = SHAPES[shape].kind != "train"
                try:
                    rep = run_cell(
                        arch, shape, multi_pod=mp, mesh=custom_mesh,
                        skip_probes=args.skip_probes,
                        serve_packed=not args.serve_float,
                        rt_overrides=(
                            {"quant_plan": args.quant_plan}
                            if args.quant_plan and serve_cell else None))
                except Exception as e:  # noqa: BLE001
                    rep = {"arch": arch, "shape": shape, "multi_pod": mp,
                           "status": "FAILED", "error": repr(e),
                           "trace": traceback.format_exc()[-2000:]}
                    failures += 1
                with open(os.path.join(args.out, key + ".json"), "w") as f:
                    json.dump(rep, f, indent=1, default=str)
                status = rep["status"]
                extra = ""
                if "roofline" in rep:
                    r = rep["roofline"]
                    extra = (f" bound={r['bound']}"
                             f" t=({r['compute_s']:.2e},{r['memory_s']:.2e},"
                             f"{r['collective_s']:.2e})s"
                             f" useful={r['useful_flop_ratio']:.2f}"
                             if r.get("useful_flop_ratio") else "")
                if "memory" in rep:
                    extra += f" hbm/dev={rep['memory']['total_hbm_bytes']/2**30:.2f}GiB"
                print(f"[{status:7s}] {key}{extra}", flush=True)
    print(f"done; {failures} failures")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
