"""Step functions + input specs shared by the trainer, server and dry-run.

`input_specs(arch, shape)` returns ShapeDtypeStruct stand-ins for every model
input of an (architecture x assigned-shape) cell — weak-type-correct,
shardable, no device allocation — exactly what `.lower()` needs.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ArchConfig, Runtime, Shape
from repro.distributed.sharding import (
    dp_axes,
    make_param_shardings,
    mesh_context,
    specs_to_shardings,
)
from repro.models import decode_step, init_caches, init_model, lm_loss
from repro.models.transformer import _logits, forward
from repro.models.transformer import prefill as prefill_fn
from repro.optim import adamw_init, adamw_update, warmup_cosine


# ------------------------------------------------------------- train state --
def init_train_state(key, cfg: ArchConfig):
    params = init_model(key, cfg)
    return {"params": params, "opt": adamw_init(params),
            "step": jnp.zeros((), jnp.int32)}


def make_train_step(cfg: ArchConfig, rt: Runtime, *, peak_lr=3e-4,
                    warmup=100, total_steps=10000):
    def train_step(state, batch):
        """batch: tokens [B, S+1]."""
        def loss_fn(p):
            return lm_loss(p, batch, cfg, rt)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"])
        lr = warmup_cosine(state["step"], peak_lr=peak_lr,
                           warmup_steps=warmup, total_steps=total_steps)
        params, opt, info = adamw_update(state["params"], grads, state["opt"], lr)
        new_state = {"params": params, "opt": opt, "step": state["step"] + 1}
        out = {"loss": loss, "lr": lr, **metrics, **info}
        return new_state, out

    return train_step


def make_prefill_step(cfg: ArchConfig, rt: Runtime):
    def prefill_step(params, tokens, caches, positions=None):
        return prefill_fn(params, tokens, cfg, rt, caches, positions)

    return prefill_step


def make_decode_step(cfg: ArchConfig, rt: Runtime):
    def step(params, token, caches, positions):
        return decode_step(params, token, cfg, rt, caches, positions)

    return step


def make_serving_steps(cfg: ArchConfig, rt: Runtime, paged: bool = False):
    """(jit'd prefill, jit'd tail-prefill-or-None, jit'd decode) for the
    continuous-batching engine.

    All donate the cache argument (the KV pool is the dominant buffer and
    is threaded through every step) and run greedy argmax *inside* the jit,
    so the only device->host traffic per step is one int32 per row.  jit
    re-specializes per input shape, so the engine's batch/prompt bucketing
    bounds the number of compilations — one per (bucket) signature, cached
    across the serving run.

    ``paged=True`` returns steps that additionally take the engine's
    device-resident block-table pool (``tbl_all`` [max_batch, pages_per_seq])
    and the step's slot ids: the per-row tables are gathered and bound to
    every layer inside the jit, so the host never assembles a block table
    per step — rows move host->device only when a request is admitted or
    its allocation grows.  The tail-prefill step is the chunked-prefill
    seam for prefix-cache hits: it runs the same prefill with
    ``rt.prefill_over_cache`` set, so the (suffix-only) queries attend over
    the gathered page pool — cached prefix pages included — instead of just
    the in-flight K/V.  For the contiguous layout it is None (no pages to
    share).
    """
    vocab = cfg.vocab

    def _greedy(logits):
        return jnp.argmax(logits[:, :vocab], axis=-1).astype(jnp.int32)

    if paged:
        import dataclasses

        from repro.serving.kv_pages import with_block_tables

        rt_tail = dataclasses.replace(rt, prefill_over_cache=True)

        def make_prefill(rt_used):
            def prefill_step(params, tokens, caches, positions, tbl_all,
                             slots):
                caches = with_block_tables(caches,
                                           jnp.take(tbl_all, slots, 0))
                logits, caches = prefill_fn(params, tokens, cfg, rt_used,
                                            caches, positions)
                return _greedy(logits), caches

            return prefill_step

        def dec_step(params, token, caches, positions, tbl_all, slots):
            caches = with_block_tables(caches, jnp.take(tbl_all, slots, 0))
            logits, caches = decode_step(params, token, cfg, rt, caches,
                                         positions)
            return _greedy(logits), caches

        return (jax.jit(make_prefill(rt), donate_argnums=(2,)),
                jax.jit(make_prefill(rt_tail), donate_argnums=(2,)),
                jax.jit(dec_step, donate_argnums=(2,)))

    def prefill_step(params, tokens, caches, positions):
        logits, caches = prefill_fn(params, tokens, cfg, rt, caches,
                                    positions)
        return _greedy(logits), caches

    def dec_step(params, token, caches, positions):
        logits, caches = decode_step(params, token, cfg, rt, caches,
                                     positions)
        return _greedy(logits), caches

    return (jax.jit(prefill_step, donate_argnums=(2,)),
            None,
            jax.jit(dec_step, donate_argnums=(2,)))


def make_ragged_step(cfg: ArchConfig, rt: Runtime):
    """One jit'd step for the ragged token-major engine: a flat [1, T] pack
    of mixed prefill-chunk and decode tokens, routed per row through
    ``slots`` (which block-table row each token belongs to, -1 = padding).

    The signature depends only on the padded token budget T (and the fixed
    max_batch/pages_per_seq of the table pool) — never on how many requests
    are prefilling vs decoding — so once the budget's shape is warm,
    steady-state recompiles are zero *by construction*, not by bucketing.

    ``emit_rows`` [max_batch] names, per slot, the packed row whose logits
    produce that request's next token (-1 = no emission this step: the
    request's prefill still has chunks to go, or the slot is empty); the
    lm head runs only on those max_batch gathered rows, and greedy argmax
    stays inside the jit like the bucketed steps."""
    from repro.serving.kv_pages import with_token_slots

    vocab = cfg.vocab

    def ragged_step(params, tokens, caches, positions, tbl_all, slots,
                    emit_rows):
        caches = with_token_slots(caches, tbl_all, slots)
        hidden, caches, _ = forward(params, tokens, cfg, rt, positions,
                                    caches, update_cache=True,
                                    return_hidden=True)
        h = jnp.take(hidden, jnp.clip(emit_rows, 0, None), axis=1)  # [1,mb,D]
        logits = _logits(params, h, cfg, rt)[0]                     # [mb, V]
        nxt = jnp.argmax(logits[:, :vocab], axis=-1).astype(jnp.int32)
        return jnp.where(emit_rows >= 0, nxt, -1), caches

    return jax.jit(ragged_step, donate_argnums=(2,))


# ------------------------------------------------------------ input specs --
def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _dp_spec(mesh):
    dpa = dp_axes() if mesh is not None else ()
    if not dpa:
        return None
    return dpa if len(dpa) > 1 else dpa[0]


def input_specs(cfg: ArchConfig, shape: Shape, mesh=None, rt: Runtime = None) -> Dict:
    """ShapeDtypeStruct stand-ins for the cell's step-function inputs."""
    rt = rt or Runtime()
    B, S = shape.batch, shape.seq

    def tok_sharding(b):
        if mesh is None:
            return None
        dspec = _dp_spec(mesh)
        size = 1
        for a in (dspec if isinstance(dspec, tuple) else (dspec,)):
            size *= mesh.shape[a]
        return NamedSharding(mesh, P(dspec if b % size == 0 else None, None))

    with mesh_context(mesh):
        if shape.kind == "train":
            return {"batch": _sds((B, S + 1), jnp.int32, tok_sharding(B))}
        if shape.kind == "prefill":
            caches = jax.eval_shape(
                lambda: init_caches(cfg, rt, batch=B, seq=S))
            caches = _shard_cache_specs(caches, mesh)
            return {
                "tokens": _sds((B, S), jnp.int32, tok_sharding(B)),
                "caches": caches,
            }
        if shape.kind == "decode":
            caches = jax.eval_shape(
                lambda: init_caches(cfg, rt, batch=B, seq=S))
            caches = _shard_cache_specs(caches, mesh)
            return {
                "token": _sds((B, 1), jnp.int32, tok_sharding(B)),
                "caches": caches,
                "positions": _sds((B, 1), jnp.int32, tok_sharding(B)),
            }
    raise ValueError(shape.kind)


def _shard_cache_specs(caches, mesh):
    """KV/state caches: shard the *batch* dim over data when divisible.
    Stacked per-repeat caches are [n_repeats, B, ...] (batch at dim 1);
    tail-block caches are [B, ...] (batch at dim 0)."""
    if mesh is None:
        return caches
    dspec = _dp_spec(mesh)
    size = 1
    for a in (dspec if isinstance(dspec, tuple) else (dspec,)):
        size *= mesh.shape[a]

    def shard_leaf(batch_dim):
        def inner(leaf):
            ax = [None] * leaf.ndim
            if leaf.ndim > batch_dim and leaf.shape[batch_dim] % size == 0 \
                    and leaf.shape[batch_dim] > 1:
                ax[batch_dim] = dspec
            return jax.ShapeDtypeStruct(
                leaf.shape, leaf.dtype,
                sharding=NamedSharding(mesh, P(*ax)))
        return inner

    return {
        "rep": jax.tree.map(shard_leaf(1), caches["rep"]),
        "tail": jax.tree.map(shard_leaf(0), caches["tail"]),
    }


def state_specs(cfg: ArchConfig, mesh, *, zero: bool = True):
    """(ShapeDtypeStruct tree, sharding tree) for the full train state."""
    state = jax.eval_shape(
        lambda: init_train_state(jax.random.PRNGKey(0), cfg))
    pspecs = make_param_shardings(state["params"], mesh)
    ospecs = {
        "mu": make_param_shardings(state["opt"]["mu"], mesh, zero=zero),
        "nu": make_param_shardings(state["opt"]["nu"], mesh, zero=zero),
        "step": P(),
    }
    specs = {"params": pspecs, "opt": ospecs, "step": P()}
    shardings = specs_to_shardings(specs, mesh)
    sds = jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        state, shardings,
    )
    return sds, shardings


def param_specs_only(cfg: ArchConfig, mesh):
    params = jax.eval_shape(
        lambda: init_model(jax.random.PRNGKey(0), cfg))
    specs = make_param_shardings(params, mesh)
    shardings = specs_to_shardings(specs, mesh)
    sds = jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        params, shardings,
    )
    return sds, shardings
