"""Launch layer: production mesh, trainer, server, multi-pod dry-run,
roofline analysis."""
