"""Per-site quantization sensitivity sweep.

Which sites *deserve* higher precision?  Starting from a uniform-W4 plan
(every site int_sim, lm_head included), flip one site group back to float at
a time and measure logits-MSE against the full-float reference.  A large MSE
drop when a group is floated means that group's quantization error dominates
— it's a candidate for a float/w4a16 rule in a mixed plan (this is how
`mixed_sensitive` was chosen; results in EXPERIMENTS.md §Mixed precision).

Shared by ``benchmarks/run.py`` (the `sensitivity` section) and
``launch/serve.py --sweep`` (emits the per-site table into the serve JSON
report).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, Runtime
from repro.models import forward, init_model


def default_groups(cfg: ArchConfig) -> Sequence[str]:
    groups = ["attn.qkv", "attn.wo", "ffn.*", "lm_head", "block[0].*"]
    if cfg.n_layers > 1:
        groups.append(f"block[{cfg.n_layers - 1}].*")
    return groups


#: uniform-plan specs for the backend-comparison table: each serving GEMM
#: backend at its native activation precision (w4a16 keeps bf16 activations;
#: the W4A4 family quantizes per-token).
COMPARE_BACKENDS: Dict[str, str] = {
    "int_sim": "*=int_sim",
    "lut4": "*=lut4",
    "w4a16": "*=w4a16/a16",
}


def sensitivity_sweep(cfg: ArchConfig, *,
                      groups: Optional[Sequence[str]] = None,
                      base_backend: str = "int_sim",
                      batch: int = 2, seq: int = 16, seed: int = 0) -> Dict:
    """Per-site-group logits-MSE table vs the uniform-W4 plan.

    Returns ``{"uniform_mse_vs_float": ..., "per_site": [{"site",
    "mse_vs_float", "delta_vs_uniform"}, ...]}`` — delta > 0 means floating
    that group removes that much of the uniform plan's quantization error.
    Also emits ``"backends"``: uniform-plan logits-MSE for every entry in
    ``COMPARE_BACKENDS``, so the table reports ``lut4`` alongside
    int4/w4a16 (identical integer math makes int_sim and lut4 rows equal —
    a drift between them is a kernel bug, not a quantization choice).
    """
    groups = list(groups) if groups is not None else list(default_groups(cfg))
    key = jax.random.PRNGKey(seed)
    params = init_model(key, cfg)
    tokens = jax.random.randint(jax.random.fold_in(key, 1),
                                (batch, seq), 0, cfg.vocab, dtype=jnp.int32)
    rt0 = Runtime(scan_layers=True, attn_impl="chunked",
                  attn_chunk_q=min(512, seq), loss_chunk=0, remat="none")

    def logits_for(**rt_kw) -> np.ndarray:
        rt = dataclasses.replace(rt0, **rt_kw)
        out = forward(params, tokens, cfg, rt)[0]
        return np.asarray(out, np.float32)[..., :cfg.vocab]

    ref = logits_for(quant_backend="float")
    # uniform baseline quantizes *everything*, lm_head included, so the
    # head's own sensitivity is measurable
    uniform_spec = f"*={base_backend}"
    mse_u = float(np.mean((logits_for(quant_plan=uniform_spec) - ref) ** 2))

    rows = []
    for g in groups:
        spec = f"{g}=float;{uniform_spec}"
        mse = float(np.mean((logits_for(quant_plan=spec) - ref) ** 2))
        rows.append({"site": g, "mse_vs_float": mse,
                     "delta_vs_uniform": mse_u - mse})
    rows.sort(key=lambda r: -r["delta_vs_uniform"])
    backend_rows = []
    for be, spec in COMPARE_BACKENDS.items():
        mse = (mse_u if spec == uniform_spec else
               float(np.mean((logits_for(quant_plan=spec) - ref) ** 2)))
        backend_rows.append({"backend": be, "plan": spec,
                             "mse_vs_float": mse})
    return {
        "arch": cfg.name,
        "base_backend": base_backend,
        "batch": batch, "seq": seq,
        "uniform_mse_vs_float": mse_u,
        "per_site": rows,
        "backends": backend_rows,
    }
