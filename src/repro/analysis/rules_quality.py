"""Rules for test-claim honesty and metrics-label cardinality.

``tolerance-claim-mismatch``: the repo's twin contract is *bit-identity* —
every Pallas kernel has an XLA twin documented (EXPERIMENTS.md, CHANGES.md)
as bit-identical, checkpoint restores round-trip exactly, and the serving
compare modes assert token identity.  A test whose name/docstring claims
exactness but asserts ``np.testing.assert_allclose`` is quietly weaker than
the contract it documents: a twin that drifts by 1 ulp would still pass.
Such tests must use ``np.testing.assert_array_equal`` (or justify the
tolerance inline).

``metrics-label-hygiene``: every label on the ``MetricsRegistry`` keys a
new time series.  The outcome taxonomy (``ok|cancelled|timeout|shed|
error``) and the dispatch labels stay useful only while their cardinality
is closed — a label value built from an f-string or ``str(x)`` can mint
unbounded series (one per rid, one per shape...) and silently blow up the
registry and every dashboard on it.
"""

from __future__ import annotations

import ast
import re
from typing import Iterable, List, Optional

from repro.analysis.core import Finding, SourceFile, dotted_name, rule

# --------------------------------------------- tolerance-claim-mismatch ----
#: exactness language in a test's name/docstring that makes assert_allclose
#: a contract violation
EXACT_CLAIM_RE = re.compile(
    r"bit[\s_-]?ident|bit[\s_-]?exact|bitwise|bit[\s_-]?for[\s_-]?bit"
    r"|identical|round[\s_-]?trip|restore",
    re.IGNORECASE)


def _is_test_file(sf: SourceFile) -> bool:
    parts = sf.rel.split("/")
    return "tests" in parts[:-1] or parts[-1].startswith("test_")


@rule("tolerance-claim-mismatch",
      "assert_allclose in a test whose name/docstring claims bit-identity "
      "/ exact round-trips — the twin contract is exact, assert it exactly")
def check_tolerance_claims(sf: SourceFile) -> Iterable[Finding]:
    if not _is_test_file(sf):
        return
    tree = sf.tree
    assert tree is not None
    yield from _visit_scope(sf, tree, context="")


def _visit_scope(sf: SourceFile, scope: ast.AST,
                 context: str) -> Iterable[Finding]:
    for node in getattr(scope, "body", []):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            ctx = f"{node.name} {ast.get_docstring(node) or ''}"
            yield from _visit_scope(sf, node, ctx)
        else:
            for call in ast.walk(node):
                if not isinstance(call, ast.Call):
                    continue
                name = dotted_name(call.func)
                if not name.endswith("assert_allclose"):
                    continue
                if EXACT_CLAIM_RE.search(context):
                    yield Finding(
                        rule="tolerance-claim-mismatch", path=sf.rel,
                        line=call.lineno, col=call.col_offset,
                        message="test claims exactness (name/docstring "
                                "says bit-identical/round-trip/restore) "
                                "but asserts allclose: use np.testing."
                                "assert_array_equal, or justify the "
                                "tolerance with an inline suppression")


# ------------------------------------------------- metrics-label-hygiene ----
_REGISTRY_METHODS = {"counter", "gauge", "histogram"}
#: kwargs of the registry methods that are not labels
_NON_LABEL_KWARGS = {"buckets"}
#: the typed request-outcome taxonomy (serving/scheduler.py); 'preempted'
#: is a trace-span outcome, not a metrics label
OUTCOME_VALUES = {"ok", "cancelled", "timeout", "shed", "error"}


def _closed_value(node: ast.AST) -> bool:
    """Literal, named constant, or attribute chain (enum member / field
    constrained elsewhere): closed cardinality.  Anything constructed at
    call time (f-string, concat, str(), %-format, subscript) is open."""
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, (ast.Name, ast.Attribute)):
        return True
    if isinstance(node, ast.IfExp):
        return _closed_value(node.body) and _closed_value(node.orelse)
    return False


@rule("metrics-label-hygiene",
      "MetricsRegistry label values must come from closed enums — "
      "dynamically formatted labels mint unbounded time series")
def check_metric_labels(sf: SourceFile) -> Iterable[Finding]:
    tree = sf.tree
    assert tree is not None
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        if not isinstance(node.func, ast.Attribute) \
                or node.func.attr not in _REGISTRY_METHODS:
            continue
        # shape filter: registry methods take (name, help, **labels) with a
        # literal metric name — a non-registry .counter() (e.g. a dict of
        # collections.Counter) won't match the two-leading-string shape
        if len(node.args) < 2:
            continue
        if not all(isinstance(a, ast.Constant) and isinstance(a.value, str)
                   for a in node.args[:2]):
            # computed name + literal help string: still clearly the
            # registry shape, so the computed name itself is the bug.
            # Anything else (e.g. collections.Counter-ish .counter(key, 5))
            # is not a registry call — out of scope.
            if not isinstance(node.args[0], ast.Constant) \
                    and isinstance(node.args[1], ast.Constant) \
                    and isinstance(node.args[1].value, str):
                yield Finding(
                    rule="metrics-label-hygiene", path=sf.rel,
                    line=node.args[0].lineno, col=node.args[0].col_offset,
                    message="metric name must be a string literal: a "
                            "computed name is an unbounded metric "
                            "namespace")
            continue
        for kw in node.keywords:
            if kw.arg is None:
                yield Finding(
                    rule="metrics-label-hygiene", path=sf.rel,
                    line=node.lineno, col=node.col_offset,
                    message="**splat labels on a registry metric cannot "
                            "be cardinality-checked — pass labels "
                            "explicitly from closed enums")
                continue
            if kw.arg in _NON_LABEL_KWARGS:
                continue
            if not _closed_value(kw.value):
                yield Finding(
                    rule="metrics-label-hygiene", path=sf.rel,
                    line=kw.value.lineno, col=kw.value.col_offset,
                    message=f"label '{kw.arg}' is built at call time "
                            f"(f-string/format/str()): label values must "
                            f"come from closed enums or literals — every "
                            f"distinct value is a new time series")
            elif kw.arg == "outcome" and isinstance(kw.value, ast.Constant) \
                    and kw.value.value not in OUTCOME_VALUES:
                yield Finding(
                    rule="metrics-label-hygiene", path=sf.rel,
                    line=kw.value.lineno, col=kw.value.col_offset,
                    message=f"outcome label {kw.value.value!r} is not in "
                            f"the typed taxonomy "
                            f"{sorted(OUTCOME_VALUES)} — extend the "
                            f"taxonomy deliberately or fix the typo")
