"""Rules for the serving hot path and Pallas kernel hygiene.

``host-sync-in-hot-path`` guards the engine's one-sync-per-step contract:
the only device->host transfer a steady-state step is allowed is the single
int32-per-row token readback (engine ``_step_*`` docstrings).  Everything
else — ``.item()`` in a loop, an ``np.asarray`` on an intermediate, a
``float()`` on a device scalar — serializes the dispatch pipeline and turns
a ~100us step into a blocking round-trip.

``pallas-kernel-hygiene`` enforces three kernel-authoring contracts:

  * no Python ``if``/``while`` on traced values inside a kernel body
    (ref loads and ``pl.program_id`` are traced — branch with ``pl.when``
    or ``jnp.where``);
  * a wrapper that launches ``pl.pallas_call`` must carry at least one
    divisibility ``assert`` (``x % b == 0``-shaped) tying its grid to its
    block shapes — Mosaic's errors for misaligned tiles are unreadable, the
    assert is the contract surface;
  * backend/interpret dispatch belongs to ``kernels.ops`` /
    ``kernels.dispatch``: a kernel module neither hardcodes
    ``interpret=True/False`` at the ``pallas_call``, omits it (Mosaic
    crash on CPU), nor consults ``jax.default_backend()`` itself.
"""

from __future__ import annotations

import ast
import re
from typing import Dict, Iterable, List, Optional, Set

from repro.analysis.core import (
    Finding,
    SourceFile,
    assigned_names,
    dotted_name,
    rule,
    stmt_scan_roots,
    walk_statements,
)

# ------------------------------------------------- host-sync-in-hot-path ----
#: per-step engine functions: between step() entry and return, device->host
#: sync is budgeted at exactly one token readback (inline-suppressed at the
#: sanctioned line)
HOT_FN_RE = re.compile(
    r"^(_step_\w+|_ragged_exec|_decode_batch|_prefill_request|_warm_ragged)$")

#: calls that force a device->host transfer when fed a device value
_SYNC_CALLS = {"np.asarray", "np.array", "numpy.asarray", "numpy.array",
               "jax.device_get"}
_SYNC_METHODS = {"item", "tolist", "block_until_ready"}
_CAST_BUILTINS = {"float", "int", "bool"}

def _rhs_is_hostlike(node: ast.AST, host: Set[str]) -> bool:
    """Does this RHS produce a host value (literal, np constructor, clock,
    len/int/float of host things)?"""
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.Tuple,
                         ast.ListComp, ast.DictComp, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        name = dotted_name(node.func)
        if name.startswith(("np.", "numpy.", "time.")):
            return True
        if name in ("len", "int", "float", "bool", "range", "sorted",
                    "list", "dict", "set", "tuple", "sum", "min", "max",
                    "self.clock", "self.trace.now"):
            return True
        return False
    if isinstance(node, (ast.Name, ast.Attribute)):
        name = dotted_name(node)
        return name in host
    if isinstance(node, ast.Subscript):
        return _rhs_is_hostlike(node.value, host)
    if isinstance(node, ast.BinOp):
        return _rhs_is_hostlike(node.left, host) \
            and _rhs_is_hostlike(node.right, host)
    return False


@rule("host-sync-in-hot-path",
      "device->host transfer (np.asarray / .item() / float()) inside a "
      "per-step engine function outside the sanctioned token readback")
def check_host_sync(sf: SourceFile) -> Iterable[Finding]:
    tree = sf.tree
    assert tree is not None
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if not HOT_FN_RE.match(fn.name):
            continue
        yield from _check_hot_fn(sf, fn)


def _check_hot_fn(sf: SourceFile, fn: ast.AST) -> Iterable[Finding]:
    host: Set[str] = set()          # names known to hold host values
    for stmt in walk_statements(getattr(fn, "body", [])):
        flagged_targets = False
        for root in stmt_scan_roots(stmt):
            for node in ast.walk(root):
                if not isinstance(node, ast.Call):
                    continue
                name = dotted_name(node.func)
                msg: Optional[str] = None
                if name in _SYNC_CALLS:
                    arg = node.args[0] if node.args else None
                    if arg is not None and not _rhs_is_hostlike(arg, host):
                        msg = (f"{name}() on a device value inside hot-path "
                               f"'{getattr(fn, 'name', '?')}' forces a "
                               f"blocking device->host transfer")
                elif (isinstance(node.func, ast.Attribute)
                      and node.func.attr in _SYNC_METHODS
                      and not _rhs_is_hostlike(node.func.value, host)):
                    msg = (f".{node.func.attr}() on a device value inside "
                           f"hot-path '{getattr(fn, 'name', '?')}' forces "
                           f"a blocking device->host transfer")
                elif name in _CAST_BUILTINS and node.args:
                    arg = node.args[0]
                    if isinstance(arg, (ast.Name, ast.Attribute,
                                        ast.Subscript)) \
                            and not _rhs_is_hostlike(arg, host):
                        msg = (f"{name}() on a device value inside "
                               f"hot-path '{getattr(fn, 'name', '?')}' "
                               f"is a hidden device->host sync")
                if msg:
                    yield Finding(rule="host-sync-in-hot-path", path=sf.rel,
                                  line=node.lineno, col=node.col_offset,
                                  message=msg)
                    flagged_targets = True
        # propagate hostness: a sync result IS host afterwards (so the
        # engine's sanctioned `nxt = np.asarray(nxt)` poisons nothing
        # downstream), and host producers stay host
        targets = assigned_names(stmt)
        if targets:
            value = getattr(stmt, "value", None)
            if flagged_targets or (
                    value is not None and _rhs_is_hostlike(value, host)):
                host.update(targets)
            elif isinstance(stmt, ast.Assign) and isinstance(
                    stmt.value, ast.Call) and dotted_name(
                        stmt.value.func) in _SYNC_CALLS:
                host.update(targets)
            else:
                host.difference_update(targets)


# ----------------------------------------------- pallas-kernel-hygiene ----
_PROGRAM_ID_CALLS = {"pl.program_id", "pl.num_programs"}


def _is_kernel_fn(fn: ast.AST) -> bool:
    args = getattr(fn, "args", None)
    if args is None:
        return False
    names = [a.arg for a in args.args + args.kwonlyargs]
    if args.vararg is not None:
        names.append(args.vararg.arg)
    return any(n.endswith("_ref") or n == "refs" for n in names)


def _kernel_file(sf: SourceFile) -> bool:
    # ops.py / dispatch.py ARE the sanctioned backend-dispatch homes; the
    # autotuner is legitimately backend-aware (cache keys, tune gating).
    parts = sf.rel.split("/")
    return ("kernels" in parts[:-1]
            and parts[-1] not in ("ops.py", "dispatch.py", "autotune.py",
                                  "__init__.py"))


def _tainted_in(node: ast.AST, tainted: Set[str]) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call) \
                and dotted_name(sub.func) in _PROGRAM_ID_CALLS:
            return True
        if isinstance(sub, ast.Subscript):
            base = dotted_name(sub.value)
            if base.endswith("_ref") or base in tainted:
                return True
        if isinstance(sub, ast.Name) and sub.id in tainted:
            return True
    return False


@rule("pallas-kernel-hygiene",
      "kernel-body Python branches on traced values, pallas_call wrappers "
      "without divisibility asserts, and interpret/backend dispatch "
      "decisions made outside kernels.ops/kernels.dispatch")
def check_pallas_hygiene(sf: SourceFile) -> Iterable[Finding]:
    tree = sf.tree
    assert tree is not None
    in_kernel_file = _kernel_file(sf)

    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if _is_kernel_fn(fn):
            yield from _check_kernel_body(sf, fn)
        yield from _check_wrapper(sf, fn, in_kernel_file)

    if in_kernel_file:
        for node in ast.walk(tree):
            if isinstance(node, ast.Call) and dotted_name(node.func) in (
                    "jax.default_backend", "jax.devices"):
                yield Finding(
                    rule="pallas-kernel-hygiene", path=sf.rel,
                    line=node.lineno, col=node.col_offset,
                    message="backend dispatch decision inside a kernel "
                            "module: route interpret/backend selection "
                            "through kernels.dispatch (ops.py picks "
                            "Mosaic/interpret/XLA-twin in one place)")


def _check_kernel_body(sf: SourceFile, fn: ast.AST) -> Iterable[Finding]:
    tainted: Set[str] = set()
    for stmt in walk_statements(getattr(fn, "body", [])):
        if isinstance(stmt, (ast.If, ast.While)) \
                and _tainted_in(stmt.test, tainted):
            yield Finding(
                rule="pallas-kernel-hygiene", path=sf.rel,
                line=stmt.lineno, col=stmt.col_offset,
                message=f"Python {'if' if isinstance(stmt, ast.If) else 'while'} "
                        f"on a traced value inside kernel body "
                        f"'{getattr(fn, 'name', '?')}': ref loads and "
                        f"pl.program_id are traced — use pl.when or "
                        f"jnp.where")
        value = getattr(stmt, "value", None)
        if value is not None and _tainted_in(value, tainted):
            tainted.update(n for n in assigned_names(stmt)
                           if "." not in n)


def _check_wrapper(sf: SourceFile, fn: ast.AST,
                   in_kernel_file: bool) -> Iterable[Finding]:
    calls = [node for node in ast.walk(fn)
             if isinstance(node, ast.Call)
             and dotted_name(node.func).endswith("pallas_call")]
    # only direct pallas_call launches in *this* function body (not in
    # nested defs, which get their own visit)
    calls = [c for c in calls if _owns(fn, c)]
    if not calls:
        return
    has_mod_assert = any(
        isinstance(stmt, ast.Assert) and any(
            isinstance(sub, ast.BinOp) and isinstance(sub.op, ast.Mod)
            for sub in ast.walk(stmt.test))
        for stmt in ast.walk(fn) if isinstance(stmt, ast.Assert))
    for call in calls:
        if not has_mod_assert:
            yield Finding(
                rule="pallas-kernel-hygiene", path=sf.rel,
                line=call.lineno, col=call.col_offset,
                message=f"'{getattr(fn, 'name', '?')}' launches "
                        f"pl.pallas_call with no grid/block divisibility "
                        f"assert (x % block == 0): misaligned tiles fail "
                        f"deep inside Mosaic — assert the contract here")
        if not in_kernel_file:
            continue
        interp = next((kw for kw in call.keywords
                       if kw.arg == "interpret"), None)
        if interp is None:
            if not any(kw.arg is None for kw in call.keywords):  # **kwargs
                yield Finding(
                    rule="pallas-kernel-hygiene", path=sf.rel,
                    line=call.lineno, col=call.col_offset,
                    message="pallas_call without interpret=: defaults to "
                            "Mosaic compilation, which aborts off-TPU — "
                            "thread interpret through "
                            "kernels.dispatch.default_interpret")
        elif isinstance(interp.value, ast.Constant):
            yield Finding(
                rule="pallas-kernel-hygiene", path=sf.rel,
                line=interp.value.lineno, col=interp.value.col_offset,
                message=f"pallas_call hardcodes interpret="
                        f"{interp.value.value!r}: dispatch belongs to "
                        f"kernels.ops/kernels.dispatch so tests, CPU twins "
                        f"and TPU runs share one policy")


def _owns(fn: ast.AST, node: ast.AST) -> bool:
    """True when ``node`` is inside ``fn`` but not inside a nested def."""
    for stmt in ast.walk(fn):
        if stmt is fn:
            continue
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            if any(sub is node for sub in ast.walk(stmt)):
                return False
    return True
