"""Contract-aware static analysis for the serving stack.

``python -m repro.analysis [paths] [--baseline analysis_baseline.json]``

The repo's core guarantees were, until this package, prose: docstrings
promised zero steady-state recompiles, comments explained which buffers are
donated, CHANGES.md recorded which XLA twins are bit-identical, and the
only machine check was the *runtime* recompile sentinel
(``observability/jit_watch.py``) — which needs a serving run to fire.  The
paper's LUT multiplier wins precisely because a 4-bit lookup table can be
verified against all 256 input pairs; this package is the software
analogue for the serving stack's invariants: every contract below is
enforced at lint time, on the AST, with no JAX import and no device.

Enforced contracts (one rule each — ``--list-rules`` for the live list):

``recompile-hazard``
    Step jits compile once per signature, then replay forever.  Python
    scalars / shape-derived values passed non-static into a jit'd step
    (weak-type and trace re-specialization), ``jax.jit`` built inside a
    loop, or ``jax.jit(f)(x)`` compile-and-invoke are all flagged.  This
    is the static twin of the jit_watch steady-state sentinel: the
    sentinel makes a recompile loud at runtime, the rule stops it from
    being written.

``donation-use-after-transfer``
    The serving steps donate the KV cache pool (``donate_argnums=(2,)`` in
    ``launch/steps.py``); a donated buffer is dead the moment the call
    dispatches.  Reading it afterwards in the same scope — without
    rebinding it from the call result — is flagged.  Donation info comes
    from local ``jax.jit(..., donate_argnums=...)`` assignments plus the
    declared engine step attributes (``rules_jit.STEP_JIT_ATTRS``).

``host-sync-in-hot-path``
    A steady-state engine step budgets exactly ONE device->host transfer:
    the int32-per-row token readback.  ``np.asarray`` / ``.item()`` /
    ``float()`` on device values anywhere else inside the per-step
    functions (``_step_*``, ``_ragged_exec``, ``_decode_batch``,
    ``_prefill_request``) is flagged; the sanctioned readbacks carry
    inline suppressions so the budget is visible in the diff.

``pallas-kernel-hygiene``
    Kernel bodies must not branch in Python on traced values (ref loads,
    ``pl.program_id``) — use ``pl.when`` / ``jnp.where``.  Wrappers that
    launch ``pl.pallas_call`` must assert their grid/block divisibility
    contracts (``x % block == 0``).  Backend dispatch (``interpret=``,
    ``jax.default_backend()``) belongs to ``kernels.ops`` /
    ``kernels.dispatch`` only.

``tolerance-claim-mismatch``
    A test whose name/docstring claims bit-identity / exact round-trips
    must assert ``np.testing.assert_array_equal``, not ``assert_allclose``
    — the twin contract is exact, so the test must be too.

``metrics-label-hygiene``
    ``MetricsRegistry`` label values must come from closed enums/literals;
    call-time-formatted values (f-strings, ``str(x)``) mint unbounded time
    series.  Literal ``outcome=`` labels must be in the typed
    ``ok|cancelled|timeout|shed|error`` taxonomy.

Suppressing a finding
---------------------
Append ``# repro: ignore[rule-name]  -- why this line is sanctioned`` to
the flagged line (or put it on a comment-only line directly above, for
lines with no column budget).  ``# repro: ignore`` with no bracket
suppresses every rule on that line.  Suppressions are for *sanctioned*
violations — the one token readback per step, a profiling probe whose
recompiles are absorbed — and should always carry the justification after
the marker.

Baseline workflow
-----------------
``analysis_baseline.json`` (repo root) holds accepted pre-existing
findings keyed by a fingerprint of (rule, path, source line), so line
drift does not invalidate it but editing a flagged line does.  CI runs::

    python -m repro.analysis --baseline analysis_baseline.json --format json

and fails only on findings NOT in the baseline.  After fixing a baselined
violation (or accepting a new one — rare, justify it), re-baseline with::

    python -m repro.analysis --baseline analysis_baseline.json --write-baseline

which prunes stale entries, keeps existing justifications, and stamps new
entries with a TODO justification a reviewer is expected to replace.
"""

from repro.analysis.core import (
    Finding,
    Rule,
    SourceFile,
    all_rules,
    analyze_file,
    analyze_paths,
    analyze_source,
    gate,
    load_baseline,
    write_baseline,
)
from repro.analysis.cli import main

__all__ = [
    "Finding",
    "Rule",
    "SourceFile",
    "all_rules",
    "analyze_file",
    "analyze_paths",
    "analyze_source",
    "gate",
    "load_baseline",
    "main",
    "write_baseline",
]
