"""Rules for the jit contracts: recompile hazards and donation discipline.

Both rules lean on the same local knowledge:

  * ``f = jax.jit(g, donate_argnums=..., static_argnums=...)`` assignments
    in the analyzed module give the analyzer per-name donation/static info.
  * The serving engine's step jits are built in ``launch/steps.py`` and
    stored on attributes — a cross-module fact the AST cannot see — so the
    engine contract is declared here: ``STEP_JIT_ATTRS`` names the
    attributes that hold donated single-signature step jits (all donate the
    cache argument at position 2, per ``make_serving_steps`` /
    ``make_ragged_step``).
"""

from __future__ import annotations

import ast
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.core import (
    Finding,
    SourceFile,
    assigned_names,
    dotted_name,
    expr_key,
    int_constants,
    rule,
    stmt_scan_roots,
    str_constants,
    walk_statements,
)

#: engine attributes that hold jits built by launch/steps.py — every one is
#: a single-signature step function with the KV cache donated at position 2
STEP_JIT_ATTRS: Dict[str, Tuple[int, ...]] = {
    "_prefill": (2,),
    "_prefill_tail": (2,),
    "_decode": (2,),
    "_ragged": (2,),
}

_JIT_NAMES = ("jax.jit", "jit")


@dataclass
class JitInfo:
    donate: Tuple[int, ...] = ()
    static_nums: Tuple[int, ...] = ()
    static_names: Tuple[str, ...] = ()


def _jit_call_info(call: ast.Call) -> Optional[JitInfo]:
    if dotted_name(call.func) not in _JIT_NAMES:
        return None
    info = JitInfo()
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            info.donate = int_constants(kw.value)
        elif kw.arg == "static_argnums":
            info.static_nums = int_constants(kw.value)
        elif kw.arg == "static_argnames":
            info.static_names = str_constants(kw.value)
    return info


def _collect_local_jits(tree: ast.AST) -> Dict[str, JitInfo]:
    """Names assigned from a ``jax.jit(...)`` call anywhere in the module
    (module level, function bodies, tuple unpacking of parallel jits)."""
    jits: Dict[str, JitInfo] = {}
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign):
            continue
        values: List[ast.AST]
        targets: List[ast.AST]
        if (isinstance(node.value, ast.Tuple)
                and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Tuple)
                and len(node.targets[0].elts) == len(node.value.elts)):
            targets = list(node.targets[0].elts)
            values = list(node.value.elts)
        else:
            targets = list(node.targets)
            values = [node.value] * len(node.targets)
        for tgt, val in zip(targets, values):
            if not isinstance(val, ast.Call):
                continue
            info = _jit_call_info(val)
            if info is None:
                continue
            key = expr_key(tgt)
            if key:
                jits[key] = info
    return jits


def _callee_info(call: ast.Call,
                 local_jits: Dict[str, JitInfo]) -> Optional[JitInfo]:
    """JitInfo for a call to a known jit'd step: a locally assigned jit
    name, or one of the engine's step-jit attributes."""
    key = expr_key(call.func)
    if key and key in local_jits:
        return local_jits[key]
    if isinstance(call.func, ast.Attribute) \
            and call.func.attr in STEP_JIT_ATTRS:
        return JitInfo(donate=STEP_JIT_ATTRS[call.func.attr])
    return None


# ------------------------------------------------------ recompile-hazard ----
_SCALAR_BUILTINS = {"len", "int", "float", "bool", "round", "min", "max",
                    "sum"}


def _is_host_scalar_expr(node: ast.AST) -> bool:
    """Python scalars and shape-derived host values: the argument classes
    that flip weak types or re-specialize a traced signature per call."""
    if isinstance(node, ast.Constant):
        return isinstance(node.value, (int, float, bool)) \
            and not isinstance(node.value, complex)
    if isinstance(node, ast.Call):
        return dotted_name(node.func) in _SCALAR_BUILTINS
    if isinstance(node, ast.Attribute) and node.attr in ("shape", "ndim",
                                                         "size"):
        return True
    if isinstance(node, ast.Subscript):
        return _is_host_scalar_expr(node.value)
    if isinstance(node, ast.BinOp):
        return _is_host_scalar_expr(node.left) \
            or _is_host_scalar_expr(node.right)
    if isinstance(node, ast.UnaryOp):
        return _is_host_scalar_expr(node.operand)
    return False


@rule("recompile-hazard",
      "Python scalars / shape-derived values passed non-static into a "
      "jit'd step, or jits created per iteration — the static twin of the "
      "jit_watch steady-state sentinel")
def check_recompile_hazard(sf: SourceFile) -> Iterable[Finding]:
    tree = sf.tree
    assert tree is not None
    local_jits = _collect_local_jits(tree)

    # jax.jit(...) nodes that sit inside a loop body
    loop_jits: Set[ast.Call] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.For, ast.While, ast.AsyncFor)):
            for sub in ast.walk(node):
                if isinstance(sub, ast.Call) \
                        and _jit_call_info(sub) is not None:
                    loop_jits.add(sub)

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        # jax.jit(f)(x): a fresh executable compiled on every execution of
        # this line — in anything called repeatedly, that is a recompile
        # per call
        if isinstance(node.func, ast.Call) \
                and _jit_call_info(node.func) is not None:
            yield Finding(
                rule="recompile-hazard", path=sf.rel,
                line=node.lineno, col=node.col_offset,
                message="jax.jit(...) compiled and invoked in one "
                        "expression: every execution pays a fresh trace + "
                        "compile; hoist the jit to module/init scope")
        if node in loop_jits and _jit_call_info(node) is not None:
            yield Finding(
                rule="recompile-hazard", path=sf.rel,
                line=node.lineno, col=node.col_offset,
                message="jax.jit(...) created inside a loop: each "
                        "iteration gets a fresh compile cache; hoist the "
                        "jit out of the loop")
        info = _callee_info(node, local_jits)
        if info is None:
            continue
        for i, arg in enumerate(node.args):
            if i in info.static_nums or i in info.donate:
                continue
            if _is_host_scalar_expr(arg):
                yield Finding(
                    rule="recompile-hazard", path=sf.rel,
                    line=arg.lineno, col=arg.col_offset,
                    message=f"Python scalar/shape-derived value passed "
                            f"non-static into jit'd step "
                            f"'{dotted_name(node.func)}' (arg {i}): "
                            f"weak-type/shape drift re-specializes the "
                            f"trace per call — pass a device array or "
                            f"declare the arg static")
        for kw in node.keywords:
            if kw.arg is None or kw.arg in info.static_names:
                continue
            if _is_host_scalar_expr(kw.value):
                yield Finding(
                    rule="recompile-hazard", path=sf.rel,
                    line=kw.value.lineno, col=kw.value.col_offset,
                    message=f"Python scalar/shape-derived value passed "
                            f"non-static into jit'd step "
                            f"'{dotted_name(node.func)}' (kwarg "
                            f"'{kw.arg}'): declare it in static_argnames "
                            f"or pass a device array")


# ------------------------------------- donation-use-after-transfer ----------
@rule("donation-use-after-transfer",
      "a buffer passed through a donated argnum and read again in the same "
      "scope — donated buffers are dead the moment the call dispatches")
def check_donation_use_after_transfer(sf: SourceFile) -> Iterable[Finding]:
    tree = sf.tree
    assert tree is not None
    local_jits = _collect_local_jits(tree)

    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        yield from _check_function(sf, fn, local_jits)


def _donating_calls(roots: List[ast.AST], local_jits: Dict[str, JitInfo]
                    ) -> List[Tuple[ast.Call, List[str]]]:
    out = []
    for root in roots:
        for node in ast.walk(root):
            if not isinstance(node, ast.Call):
                continue
            info = _callee_info(node, local_jits)
            if info is None or not info.donate:
                continue
            donated = [key for i in info.donate if i < len(node.args)
                       for key in (expr_key(node.args[i]),) if key]
            if donated:
                out.append((node, donated))
    return out


def _check_function(sf: SourceFile, fn: ast.AST,
                    local_jits: Dict[str, JitInfo]) -> Iterable[Finding]:
    #: donated-expr key -> line where it was donated
    dead: Dict[str, int] = {}
    body = getattr(fn, "body", [])
    for stmt in walk_statements(body):
        roots = stmt_scan_roots(stmt)
        # 1) loads of currently-dead buffers in this statement's own exprs
        if dead:
            for root in roots:
                for node in ast.walk(root):
                    if not isinstance(node, (ast.Name, ast.Attribute)):
                        continue
                    if not isinstance(getattr(node, "ctx", None), ast.Load):
                        continue
                    key = expr_key(node)
                    if key in dead:
                        yield Finding(
                            rule="donation-use-after-transfer", path=sf.rel,
                            line=node.lineno, col=node.col_offset,
                            message=f"'{key}' was donated to a jit at line "
                                    f"{dead[key]} and read again here: the "
                                    f"buffer is dead after transfer — "
                                    f"rebind it from the call's result")
                        del dead[key]      # one finding per donation site
        # 2) donations dispatched by this statement kill their buffers
        for call, donated in _donating_calls(roots, local_jits):
            for key in donated:
                dead[key] = call.lineno
        # 3) stores (including rebinding from the call result) revive
        for key in assigned_names(stmt):
            dead.pop(key, None)
