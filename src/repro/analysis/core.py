"""Analyzer framework: source model, rule registry, suppressions, baseline.

Zero-dependency (stdlib ``ast`` + ``tokenize`` only) so the lint job can run
before jax is even importable.  The moving parts:

  * ``SourceFile``   -- one parsed module: text, AST, and the per-line
                        suppression table parsed from ``# repro: ignore[...]``
                        comments (comments found via ``tokenize``, so the
                        marker inside a string literal does not suppress).
  * ``@rule(name)``  -- registers a check function ``(SourceFile) ->
                        Iterable[Finding]`` in the global registry.
  * ``analyze_paths``-- walk files, run rules, drop suppressed findings,
                        assign stable fingerprints.
  * baseline helpers -- load/gate/write the committed ``analysis_baseline
                        .json`` so only *new* violations fail CI.

Fingerprints are ``rule|path|<stripped source line>|<occurrence>`` — stable
under unrelated edits that shift line numbers, invalidated exactly when the
flagged line itself changes (which is when a human should re-look anyway).
"""

from __future__ import annotations

import ast
import io
import json
import os
import re
import tokenize
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Set, Tuple

#: suppression marker: ``# repro: ignore`` (all rules) or
#: ``# repro: ignore[rule-a,rule-b] optional justification``
SUPPRESS_RE = re.compile(
    r"#\s*repro:\s*ignore(?:\[([A-Za-z0-9_,\- ]+)\])?")

BASELINE_VERSION = 1


@dataclass
class Finding:
    rule: str
    path: str          # repo-relative, posix separators
    line: int          # 1-based
    col: int           # 0-based
    message: str
    text: str = ""     # stripped source line (fingerprint ingredient)
    fingerprint: str = ""

    def location(self) -> str:
        return f"{self.path}:{self.line}:{self.col + 1}"

    def to_dict(self) -> Dict[str, object]:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message,
                "fingerprint": self.fingerprint}


class SourceFile:
    """One parsed python module plus its suppression table."""

    def __init__(self, path: str, rel: str, text: str):
        self.path = path
        self.rel = rel.replace(os.sep, "/")
        self.text = text
        self.lines: List[str] = text.splitlines()
        self.tree: Optional[ast.AST] = None
        self.parse_error: Optional[SyntaxError] = None
        try:
            self.tree = ast.parse(text)
        except SyntaxError as e:        # surfaced as a finding by the runner
            self.parse_error = e
        #: line -> None (all rules) | set of rule names
        self.suppressions: Dict[int, Optional[Set[str]]] = {}
        self._comment_only: Set[int] = set()
        self._scan_comments()

    def _scan_comments(self) -> None:
        try:
            toks = tokenize.generate_tokens(io.StringIO(self.text).readline)
            for tok in toks:
                if tok.type != tokenize.COMMENT:
                    continue
                lineno = tok.start[0]
                if tok.line.strip().startswith("#"):
                    self._comment_only.add(lineno)
                m = SUPPRESS_RE.search(tok.string)
                if not m:
                    continue
                if m.group(1) is None:
                    self.suppressions[lineno] = None
                else:
                    names = {r.strip() for r in m.group(1).split(",")
                             if r.strip()}
                    prev = self.suppressions.get(lineno)
                    if prev is None and lineno in self.suppressions:
                        continue                      # already suppress-all
                    self.suppressions[lineno] = (names if prev is None
                                                 else prev | names)
        except (tokenize.TokenError, IndentationError):
            pass                                      # parse error reported

    def _line_suppresses(self, lineno: int, rule_name: str) -> bool:
        if lineno not in self.suppressions:
            return False
        names = self.suppressions[lineno]
        return names is None or rule_name in names

    def suppressed(self, lineno: int, rule_name: str) -> bool:
        """A finding on ``lineno`` is suppressed by a marker on the same
        line, or on a directly preceding comment-only line (for statements
        too long to carry the marker inline)."""
        if self._line_suppresses(lineno, rule_name):
            return True
        prev = lineno - 1
        return prev in self._comment_only and self._line_suppresses(
            prev, rule_name)

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""


# ------------------------------------------------------------- registry ----
CheckFn = Callable[[SourceFile], Iterable[Finding]]


@dataclass
class Rule:
    name: str
    summary: str
    check: CheckFn


RULES: Dict[str, Rule] = {}


def rule(name: str, summary: str) -> Callable[[CheckFn], CheckFn]:
    def deco(fn: CheckFn) -> CheckFn:
        if name in RULES:
            raise ValueError(f"duplicate rule {name!r}")
        RULES[name] = Rule(name=name, summary=summary, check=fn)
        return fn
    return deco


def all_rules() -> Dict[str, Rule]:
    # import for side effect: rule modules self-register on first use
    from repro.analysis import rules_hotpath  # noqa: F401
    from repro.analysis import rules_jit      # noqa: F401
    from repro.analysis import rules_quality  # noqa: F401
    return dict(RULES)


# ---------------------------------------------------------- AST helpers ----
def dotted_name(node: ast.AST) -> str:
    """``jax.jit`` / ``np.testing.assert_allclose`` / ``self.metrics.counter``
    as a dotted string; '' when the expression is not a plain name chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def expr_key(node: ast.AST) -> str:
    """Stable key for the simple lvalue-ish expressions the donation rule
    tracks: a bare name or a short attribute chain (``self.caches``)."""
    name = dotted_name(node)
    return name if name and name.count(".") <= 2 else ""


def walk_statements(body: Sequence[ast.stmt]) -> Iterable[ast.stmt]:
    """Yield statements in source order, recursing through compound
    statements (a linear approximation of control flow that matches how the
    serving code is written: straight-line step bodies with shallow
    branches).  Nested function/class bodies are NOT entered — they execute
    on their own schedule and get their own pass."""
    for stmt in body:
        yield stmt
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        for attr in ("body", "orelse", "finalbody"):
            inner = getattr(stmt, attr, None)
            if inner:
                yield from walk_statements(inner)
        for handler in getattr(stmt, "handlers", ()) or ():
            yield from walk_statements(handler.body)


def stmt_scan_roots(stmt: ast.stmt) -> List[ast.AST]:
    """The expression nodes a linear walk should scan for *this* statement:
    the whole node for simple statements, only the header expressions for
    compound ones (their bodies are yielded separately by
    ``walk_statements``, so scanning the full subtree would double-count)."""
    if isinstance(stmt, (ast.If, ast.While)):
        return [stmt.test]
    if isinstance(stmt, (ast.For, ast.AsyncFor)):
        return [stmt.iter]
    if isinstance(stmt, (ast.With, ast.AsyncWith)):
        return [item.context_expr for item in stmt.items]
    if isinstance(stmt, (ast.Try, ast.FunctionDef, ast.AsyncFunctionDef,
                         ast.ClassDef)):
        return []
    return [stmt]


def assigned_names(stmt: ast.stmt) -> Set[str]:
    """expr_key for every target this statement stores to."""
    out: Set[str] = set()

    def add_target(t: ast.AST) -> None:
        if isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                add_target(e)
        elif isinstance(t, ast.Starred):
            add_target(t.value)
        else:
            key = expr_key(t)
            if key:
                out.add(key)

    if isinstance(stmt, ast.Assign):
        for t in stmt.targets:
            add_target(t)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        add_target(stmt.target)
    elif isinstance(stmt, (ast.For, ast.AsyncFor)):
        add_target(stmt.target)
    elif isinstance(stmt, (ast.With, ast.AsyncWith)):
        for item in stmt.items:
            if item.optional_vars is not None:
                add_target(item.optional_vars)
    return out


def int_constants(node: ast.AST) -> Tuple[int, ...]:
    """Integer literals inside a (possibly tuple/list) constant expression —
    how ``donate_argnums=(2,)`` / ``static_argnums=0`` are written."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out: List[int] = []
        for e in node.elts:
            out.extend(int_constants(e))
        return tuple(out)
    return ()


def str_constants(node: ast.AST) -> Tuple[str, ...]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out: List[str] = []
        for e in node.elts:
            out.extend(str_constants(e))
        return tuple(out)
    return ()


# --------------------------------------------------------------- runner ----
def iter_py_files(paths: Sequence[str]) -> List[str]:
    files: List[str] = []
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                files.append(p)
            continue
        for root, dirs, names in os.walk(p):
            dirs[:] = sorted(d for d in dirs
                             if not d.startswith(".") and d != "__pycache__")
            for n in sorted(names):
                if n.endswith(".py"):
                    files.append(os.path.join(root, n))
    return sorted(set(files))


def analyze_file(path: str, rel: Optional[str] = None,
                 rules: Optional[Dict[str, Rule]] = None) -> List[Finding]:
    rules = rules if rules is not None else all_rules()
    with open(path, encoding="utf-8") as f:
        text = f.read()
    return analyze_source(text, rel if rel is not None else path, rules)


def analyze_source(text: str, rel: str,
                   rules: Optional[Dict[str, Rule]] = None) -> List[Finding]:
    rules = rules if rules is not None else all_rules()
    sf = SourceFile(rel, rel, text)
    if sf.parse_error is not None:
        e = sf.parse_error
        return [Finding(rule="syntax-error", path=sf.rel,
                        line=e.lineno or 1, col=(e.offset or 1) - 1,
                        message=f"file does not parse: {e.msg}")]
    out: List[Finding] = []
    for r in rules.values():
        for finding in r.check(sf):
            if not sf.suppressed(finding.line, finding.rule):
                finding.text = sf.line_text(finding.line)
                out.append(finding)
    out.sort(key=lambda f: (f.line, f.col, f.rule))
    return out


def analyze_paths(paths: Sequence[str],
                  rules: Optional[Dict[str, Rule]] = None,
                  root: Optional[str] = None) -> List[Finding]:
    rules = rules if rules is not None else all_rules()
    root = root or os.getcwd()
    findings: List[Finding] = []
    for path in iter_py_files(paths):
        rel = os.path.relpath(path, root)
        findings.extend(analyze_file(path, rel=rel, rules=rules))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    assign_fingerprints(findings)
    return findings


def assign_fingerprints(findings: Sequence[Finding]) -> None:
    seen: Dict[Tuple[str, str, str], int] = {}
    for f in findings:
        key = (f.rule, f.path, f.text)
        n = seen.get(key, 0)
        seen[key] = n + 1
        f.fingerprint = f"{f.rule}|{f.path}|{f.text}|{n}"


# ------------------------------------------------------------- baseline ----
def load_baseline(path: str) -> Dict[str, Dict[str, str]]:
    """Baseline entries keyed by fingerprint.  Missing file = empty baseline
    (first run bootstraps with --write-baseline)."""
    try:
        with open(path, encoding="utf-8") as f:
            data = json.load(f)
    except (OSError, ValueError):
        return {}
    if not isinstance(data, dict):
        return {}
    entries = data.get("entries", {})
    return entries if isinstance(entries, dict) else {}


def gate(findings: Sequence[Finding],
         baseline: Dict[str, Dict[str, str]]
         ) -> Tuple[List[Finding], List[Finding], List[str]]:
    """Split findings into (new, known-baselined); also return the stale
    baseline fingerprints whose violations no longer exist (fixed — prune
    them with --write-baseline so they cannot mask future regressions)."""
    new: List[Finding] = []
    known: List[Finding] = []
    live = {f.fingerprint for f in findings}
    for f in findings:
        (known if f.fingerprint in baseline else new).append(f)
    stale = sorted(fp for fp in baseline if fp not in live)
    return new, known, stale


def write_baseline(path: str, findings: Sequence[Finding],
                   old: Optional[Dict[str, Dict[str, str]]] = None) -> None:
    """Persist current findings as the accepted baseline.  Justifications
    from surviving old entries are preserved; new entries get a placeholder
    a reviewer is expected to fill in."""
    old = old or {}
    entries: Dict[str, Dict[str, str]] = {}
    for f in sorted(findings, key=lambda f: f.fingerprint):
        prev = old.get(f.fingerprint, {})
        entries[f.fingerprint] = {
            "rule": f.rule,
            "path": f.path,
            "message": f.message,
            "justification": prev.get("justification",
                                      "TODO: justify or fix"),
        }
    payload = {"version": BASELINE_VERSION, "entries": entries}
    tmp = path + ".tmp"
    with open(tmp, "w", encoding="utf-8") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)
