"""Command-line driver for ``python -m repro.analysis``."""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Sequence

from repro.analysis.core import (
    Finding,
    all_rules,
    analyze_paths,
    gate,
    load_baseline,
    write_baseline,
)

DEFAULT_PATHS = ("src", "tests")


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Contract-aware static analysis for the serving stack "
                    "(jit/donation/recompile/bit-identity invariants).")
    p.add_argument("paths", nargs="*", default=list(DEFAULT_PATHS),
                   help="files or directories to analyze "
                        "(default: src tests)")
    p.add_argument("--format", choices=("human", "json"), default="human")
    p.add_argument("--baseline", metavar="FILE", default=None,
                   help="committed baseline; only findings NOT in it fail")
    p.add_argument("--write-baseline", action="store_true",
                   help="accept all current findings into --baseline "
                        "(preserving existing justifications) and exit 0")
    p.add_argument("--rules", metavar="R1,R2", default=None,
                   help="run only these rules")
    p.add_argument("--list-rules", action="store_true")
    return p


def _select_rules(spec: Optional[str]):
    rules = all_rules()
    if spec is None:
        return rules
    wanted = [r.strip() for r in spec.split(",") if r.strip()]
    unknown = [r for r in wanted if r not in rules]
    if unknown:
        raise SystemExit(f"unknown rule(s): {', '.join(unknown)} "
                         f"(see --list-rules)")
    return {name: rules[name] for name in wanted}


def _report_json(findings: List[Finding], new: List[Finding],
                 known: List[Finding], stale: List[str]) -> str:
    return json.dumps({
        "version": 1,
        "counts": {"total": len(findings), "new": len(new),
                   "baselined": len(known), "stale_baseline": len(stale)},
        "findings": [f.to_dict() for f in findings],
        "new": [f.fingerprint for f in new],
        "stale_baseline": stale,
    }, indent=1, sort_keys=True)


def _report_human(findings: List[Finding], new: List[Finding],
                  known: List[Finding], stale: List[str],
                  baselined: bool) -> str:
    lines: List[str] = []
    for f in (new if baselined else findings):
        lines.append(f"{f.location()}: [{f.rule}] {f.message}")
    if baselined and known:
        lines.append(f"  ({len(known)} baselined finding(s) suppressed; "
                     f"see the baseline file for justifications)")
    for fp in stale:
        lines.append(f"  stale baseline entry (violation fixed — prune "
                     f"with --write-baseline): {fp}")
    bad = new if baselined else findings
    lines.append(f"{len(bad)} new finding(s), {len(findings)} total.")
    return "\n".join(lines)


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    rules = _select_rules(args.rules)
    if args.list_rules:
        for r in rules.values():
            print(f"{r.name}: {r.summary}")
        return 0

    findings = analyze_paths(args.paths, rules=rules)

    baseline: Dict[str, Dict[str, str]] = {}
    if args.baseline:
        baseline = load_baseline(args.baseline)
    new, known, stale = gate(findings, baseline)

    if args.write_baseline:
        if not args.baseline:
            print("--write-baseline requires --baseline FILE",
                  file=sys.stderr)
            return 2
        write_baseline(args.baseline, findings, old=baseline)
        print(f"wrote {len(findings)} entr(ies) to {args.baseline}")
        return 0

    if args.format == "json":
        print(_report_json(findings, new, known, stale))
    else:
        print(_report_human(findings, new, known, stale,
                            baselined=bool(args.baseline)))

    return 1 if new else 0
