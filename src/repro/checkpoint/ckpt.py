"""Atomic, multihost-aware, elastic checkpointing.

Layout:  <dir>/step_<N>/
             manifest.json          (step, tree structure, shapes, dtypes,
                                     mesh metadata, process count)
             shard_<p>.npz          (one file per host: that host's
                                     addressable param shards, fully
                                     replicated params only on host 0)

Properties needed at 1000+-node scale and tested here:

  * **atomicity** -- writes go to `step_<N>.tmp_<uuid>` then `os.replace`
    into place; a crash mid-save never corrupts the latest checkpoint;
  * **resume-from-latest** -- `latest_step` scans for complete manifests
    (incomplete/tmp dirs are ignored and garbage-collected);
  * **elastic restore** -- arrays are saved logically (full value per leaf,
    assembled host-side); `restore_checkpoint` re-`device_put`s them under
    *any* new mesh/sharding, so a job may restart on a different pod count;
  * **retention** -- keep-last-k garbage collection.

On multi-host runs each host saves only `jax.process_index()` files; in this
single-process container that degenerates to one shard file, but the code
paths are written for N processes.
"""

from __future__ import annotations

import json
import os
import shutil
import time
import uuid
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for kp, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        out[key] = leaf
    return out


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra_meta: Optional[Dict] = None) -> str:
    """Atomic save. Returns the final checkpoint path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + f".tmp_{uuid.uuid4().hex[:8]}"
    os.makedirs(tmp, exist_ok=True)

    flat = _flatten(tree)
    arrays, dtypes = {}, {}
    for k, v in flat.items():
        a = np.asarray(jax.device_get(v))
        dtypes[k] = a.dtype.name            # logical dtype (pre-conversion)
        if a.dtype.name == "bfloat16":      # npz has no bf16: store f32 (lossless)
            a = a.astype(np.float32)
        arrays[k] = a
    pidx = jax.process_index()
    np.savez(os.path.join(tmp, f"shard_{pidx}.npz"), **arrays)

    if pidx == 0:
        manifest = {
            "step": step,
            "time": time.time(),
            "process_count": jax.process_count(),
            "keys": sorted(arrays.keys()),
            "shapes": {k: list(v.shape) for k, v in arrays.items()},
            "dtypes": dtypes,
        }
        manifest.update(extra_meta or {})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
    os.replace(tmp, final) if not os.path.exists(final) else shutil.rmtree(tmp)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        path = os.path.join(directory, name)
        if name.startswith("step_") and ".tmp_" in name:
            shutil.rmtree(path, ignore_errors=True)      # gc partial saves
            continue
        if name.startswith("step_") and \
                os.path.exists(os.path.join(path, "manifest.json")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def _load_shards(path: str, manifest: Dict) -> Dict[str, np.ndarray]:
    """Assemble every host's shard file into one {key: array} map."""
    data: Dict[str, np.ndarray] = {}
    for p in range(manifest["process_count"]):
        fn = os.path.join(path, f"shard_{p}.npz")
        if os.path.exists(fn):
            with np.load(fn) as z:
                for k in z.files:
                    data[k] = z[k]
    return data


def restore_checkpoint(directory: str, step: int, like: Any,
                       shardings: Optional[Any] = None) -> Any:
    """Restore into the structure of `like`; if `shardings` (a pytree of
    jax.sharding.Sharding) is given, device_put each leaf accordingly --
    this is the elastic-resharding path (new mesh shape is fine)."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = _load_shards(path, manifest)

    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    flat_shard = (jax.tree_util.tree_leaves(shardings)
                  if shardings is not None else [None] * len(flat_like))
    leaves = []
    for (kp, leaf), shd in zip(flat_like, flat_shard):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        arr = data[key]
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        if shd is not None:
            leaves.append(jax.device_put(arr, shd))
        else:
            leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ----------------------------------------------------------- quantized ----
#: manifest tag identifying serving-ready packed checkpoints
QUANTIZED_FORMAT = "quantized-v1"


def save_quantized(directory: str, step: int, params, cfg, rt=None,
                   plan=None, min_size: int = 1 << 12) -> str:
    """Quantize-and-save: pack float-master `params` per the active
    QuantPlan (every quantized-serving site becomes uint8 K-packed nibbles +
    bf16 scales — ~4x smaller artifacts than float masters) and store the
    plan itself in the manifest.  Reuses the atomic `.tmp_` + os.replace
    machinery of `save_checkpoint`.

    Pass either `plan` (a QuantPlan) or `rt` (a Runtime whose
    quant_plan/quant_backend selects one).  Returns the checkpoint path.
    """
    from repro.core.quant_plan import (
        CKPT_PACKED, active_plan, plan_pack_tree, plan_to_dict,
    )

    if plan is None:
        assert rt is not None, "save_quantized needs a plan or a Runtime"
        plan = active_plan(cfg, rt)
    site_backends: Dict[str, str] = {}
    packed = plan_pack_tree(params, cfg, plan, min_size=min_size,
                            backends=CKPT_PACKED, scale_dtype=jnp.bfloat16,
                            site_log=site_backends)
    # per-site backend record: which kernel family each packed site's
    # nibbles were laid out for.  restore_quantized checks it against the
    # serving plan so e.g. a lut4 site rebuilds table-lookup serving
    # instead of silently dropping to nibble-unpack w4a4.
    return save_checkpoint(
        directory, step, packed,
        extra_meta={"format": QUANTIZED_FORMAT, "arch": cfg.name,
                    "plan": plan_to_dict(plan),
                    "site_backends": site_backends})


def restore_quantized(directory: str, step: Optional[int] = None,
                      *, cfg=None, rt=None):
    """Restore a quantized checkpoint into a serving-ready packed tree —
    no float master, no `like` template, no re-pack at load.  The tree is
    rebuilt directly from the manifest keys (uint8 nibbles stay uint8;
    bf16 leaves round-trip bit-exactly through the f32 npz encoding).

    The restored tree only serves correctly under the plan it was saved
    with — per-site backends and the packed/float split are baked into the
    weights.  Pass the serving `cfg` + `rt` to assert their active plan
    matches the stored one (strongly recommended: a mismatched Runtime
    would silently route packed sites through the wrong backend math).

    Returns (params_tree, manifest); the stored plan is
    `quant_plan.plan_from_dict(manifest["plan"])`.
    """
    if step is None:
        step = latest_step(directory)
        assert step is not None, f"no checkpoint in {directory}"
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest.get("format") == QUANTIZED_FORMAT, (
        f"{path} is not a quantized checkpoint "
        f"(format={manifest.get('format')!r}); use restore_checkpoint")
    if cfg is not None and rt is not None:
        from repro.core.quant_plan import active_plan, plan_from_dict

        stored = plan_from_dict(manifest["plan"])
        live = active_plan(cfg, rt)
        # per-site first: when the plans diverge, name the exact site and
        # backend pair that would serve wrong-kernel math (the manifest's
        # site_backends map was recorded at pack time; older checkpoints
        # without it fall through to the whole-plan rules check)
        for site, saved_be in manifest.get("site_backends", {}).items():
            live_be = live.resolve(site).backend
            assert live_be == saved_be, (
                f"site {site!r} does not match the plan this checkpoint was "
                f"saved with: packed for backend {saved_be!r} but the "
                f"runtime plan {live.name!r} resolves it to {live_be!r}; "
                f"restoring would serve the wrong kernel math — set "
                f"Runtime.quant_plan to the stored plan ({stored.name!r})")
        assert live.rules == stored.rules, (
            f"runtime plan {live.name!r} does not match the plan this "
            f"checkpoint was saved with ({stored.name!r}); set "
            f"Runtime.quant_plan to the stored plan")
    data = _load_shards(path, manifest)

    tree: Dict[str, Any] = {}
    for key in manifest["keys"]:
        leaf = jnp.asarray(data[key],
                           dtype=jnp.dtype(manifest["dtypes"][key]))
        node = tree
        parts = key.split("/")
        for part in parts[:-1]:
            node = node.setdefault(part, {})
        node[parts[-1]] = leaf
    return tree, manifest


class CheckpointManager:
    """save-every-N + keep-last-k + resume; preemption-safe."""

    def __init__(self, directory: str, save_every: int = 100, keep: int = 3):
        self.directory = directory
        self.save_every = save_every
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def maybe_save(self, step: int, tree: Any, force: bool = False,
                   extra_meta: Optional[Dict] = None):
        if force or (step > 0 and step % self.save_every == 0):
            save_checkpoint(self.directory, step, tree, extra_meta)
            self._gc()
            return True
        return False

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and ".tmp_" not in n
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:08d}"),
                ignore_errors=True,
            )

    def latest(self) -> Optional[int]:
        return latest_step(self.directory)

    def restore(self, like: Any, shardings=None, step: Optional[int] = None):
        step = step if step is not None else self.latest()
        assert step is not None, "no checkpoint to restore"
        return restore_checkpoint(self.directory, step, like, shardings), step
