"""Checkpointing substrate (float masters + quantized serving format)."""
from .ckpt import (  # noqa: F401
    CheckpointManager,
    QUANTIZED_FORMAT,
    latest_step,
    restore_checkpoint,
    restore_quantized,
    save_checkpoint,
    save_quantized,
)
