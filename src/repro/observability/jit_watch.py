"""Recompile sentinel: count jit cache misses per step function.

The serving engine's throughput story rides on steady-state decode being
compile-free: every bucket shape compiles once (ideally during warmup) and
every subsequent step replays the cached executable.  A silent recompile —
a weak-type flip, a donation mismatch, a cache tree whose structure drifts
between calls — turns a ~100us step into a multi-second one and *still
produces correct tokens*, so nothing catches it unless compilation itself
is measured.  This is the bucket-recompile waste ROADMAP item 1 exists to
kill; the sentinel makes it a number before it gets fixed.

Mechanism: each registered jit'd callable exposes ``_cache_size()`` (the
count of cached executables).  ``after_call(name, shape)`` takes the delta
since the previous poll and attributes it to the shape key of the call
that just ran:

  * delta > 0, shape never seen       -> a *new-bucket compile* (expected:
    warmup, or a mid-run bucket first hit).  Counted in
    ``jit_compiles_total{fn=...}``.
  * delta > 0, shape seen before      -> a *steady-state recompile* — the
    loud failure mode.  Counted in
    ``jit_recompiles_steady_state_total{fn=...}`` and, under
    ``strict=True`` (tests), raised as ``RecompileError`` on the spot with
    the triggering fn/shape/step.

Fallback: when the callable doesn't expose ``_cache_size`` (a stub, a
non-jit wrapper, a future jax that renames the private API), shape-key
novelty approximates the delta — new shapes count as compiles, and
steady-state detection degrades to never-fires rather than false-fires.
"""

from __future__ import annotations

from typing import Dict, List, Optional

from repro.observability.metrics import NULL_REGISTRY

#: events kept verbatim in snapshots (full history stays in self.events)
_SNAPSHOT_EVENTS = 32


class RecompileError(RuntimeError):
    """A registered step function recompiled for an already-seen shape."""


class JitWatch:
    def __init__(self, registry=None, strict: bool = False):
        self.registry = registry if registry is not None else NULL_REGISTRY
        self.strict = strict
        self._fns: Dict[str, object] = {}
        self._last: Dict[str, int] = {}
        self._seen: Dict[str, set] = {}
        self.by_fn: Dict[str, int] = {}
        self.events: List[Dict] = []
        self.total = 0
        self.steady_state = 0

    @property
    def enabled(self) -> bool:
        return True

    # ---------------------------------------------------------- plumbing --
    def _size(self, name: str) -> Optional[int]:
        try:
            return int(self._fns[name]._cache_size())
        except (AttributeError, TypeError):
            return None

    def register(self, name: str, fn) -> None:
        """Start watching a jit'd callable.  Safe to call with fn=None
        (layouts without a tail-prefill step just skip it)."""
        if fn is None:
            return
        self._fns[name] = fn
        self._last[name] = self._size(name) or 0
        self._seen[name] = set()
        self.by_fn.setdefault(name, 0)

    def absorb(self, name: Optional[str] = None) -> None:
        """Re-baseline cache sizes without counting — for probe calls the
        engine makes outside the serving loop (``profile()``), whose
        compiles must not masquerade as the next real step's recompile."""
        for n in ([name] if name else list(self._fns)):
            self._last[n] = self._size(n) or self._last[n]

    # ------------------------------------------------------------- polling --
    def after_call(self, name: str, shape, step: Optional[int] = None) -> int:
        """Attribute any cache growth since the last poll to the call that
        just ran (`shape` is its bucket signature).  Returns the delta."""
        if name not in self._fns:
            return 0
        shape = tuple(int(s) for s in shape)
        seen = self._seen[name]
        first = shape not in seen
        seen.add(shape)
        size = self._size(name)
        if size is None:                       # no cache API: novelty proxy
            delta = 1 if first else 0
        else:
            delta = size - self._last[name]
            self._last[name] = size
        if delta <= 0:
            return 0
        self.total += delta
        self.by_fn[name] = self.by_fn.get(name, 0) + delta
        self.registry.counter(
            "jit_compiles_total",
            "jit cache misses per step function", fn=name).inc(delta)
        event = {"fn": name, "shape": list(shape), "step": step,
                 "steady_state": not first}
        self.events.append(event)
        if not first:
            self.steady_state += delta
            self.registry.counter(
                "jit_recompiles_steady_state_total",
                "recompiles for already-seen bucket shapes (should be 0)",
                fn=name).inc(delta)
            if self.strict:
                raise RecompileError(
                    f"steady-state recompile: {name} recompiled for "
                    f"already-seen shape {shape} at step {step} "
                    f"(+{delta} cache entries)")
        return delta

    # ------------------------------------------------------------- export --
    def snapshot(self) -> Dict:
        return {
            "total": self.total,
            "steady_state": self.steady_state,
            "by_fn": dict(self.by_fn),
            "events": self.events[-_SNAPSHOT_EVENTS:],
        }


class NullJitWatch:
    """Telemetry-off sentinel: records nothing, never raises."""

    enabled = False
    strict = False
    total = 0
    steady_state = 0

    def register(self, name, fn):
        pass

    def absorb(self, name=None):
        pass

    def after_call(self, name, shape, step=None):
        return 0

    def snapshot(self):
        return {"total": 0, "steady_state": 0, "by_fn": {}, "events": []}


NULL_JIT_WATCH = NullJitWatch()
