"""Metrics registry: counters, gauges, bounded-bucket histograms.

Dependency-free (stdlib only) so every layer of the serving stack — the
scheduler, the page pool, the engine step loop, even the kernels' dispatch
wrappers — can record without importing jax or numpy.  A registry renders
two ways:

  * ``render_text()``  -- Prometheus text exposition (the format a real
                          deployment's /metrics endpoint would serve; the
                          opendatahub model-serving tests scrape exactly
                          this shape)
  * ``snapshot()``     -- a JSON-able dict merged into serving reports
                          (serve.py) and engine ``stats()``

Histograms are *bounded*: a fixed bucket ladder (geometric by default — the
right shape for latencies spanning 10us jit-cached decode steps to multi-
second preemption storms) plus exact count/sum/min/max.  Percentiles are
estimated by linear interpolation inside the bucket holding the target rank
and clamped to the observed [min, max], so a single-observation histogram
reports that observation exactly — memory stays O(buckets) no matter how
many requests flow through.

Metrics never touch the model's math: every mutation is a host-side float
or int update, which is what makes "telemetry on vs off is token-identical"
(tests/test_observability.py) trivially true by construction.
"""

from __future__ import annotations

import bisect
import threading
from typing import Dict, List, Optional, Tuple

#: default histogram ladder: 10us .. ~84s, x2 per bucket (latency-shaped)
TIME_BUCKETS_US: Tuple[float, ...] = tuple(
    float(10 * (1 << i)) for i in range(24))

#: small-count ladder (batch sizes, page counts): 1 .. 512, x2 per bucket
COUNT_BUCKETS: Tuple[float, ...] = tuple(float(1 << i) for i in range(10))

_LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, str]) -> _LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _label_str(key: _LabelKey) -> str:
    if not key:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in key) + "}"


class Counter:
    """Monotonically increasing value."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        assert n >= 0, f"counters only go up (inc({n}))"
        self.value += n


class Gauge:
    """A value that can go anywhere."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v) -> None:
        self.value = float(v)

    def inc(self, n=1) -> None:
        self.value += n


class Histogram:
    """Bounded-bucket histogram with interpolated percentiles.

    ``bounds[i]`` is the inclusive upper edge of bucket ``i``; the final
    (overflow) bucket is open-ended.  ``percentile(q)`` walks the cumulative
    counts to the bucket holding rank ``q/100 * count``, interpolates
    linearly inside it, and clamps to the exact observed [min, max] — so
    degenerate distributions (one value, all-equal values) come back exact
    and tails never extrapolate past data that was actually seen.
    """

    __slots__ = ("bounds", "counts", "n", "total", "vmin", "vmax")

    def __init__(self, buckets: Tuple[float, ...] = TIME_BUCKETS_US):
        self.bounds: Tuple[float, ...] = tuple(sorted(float(b)
                                                      for b in buckets))
        assert self.bounds, "histogram needs at least one bucket bound"
        self.counts: List[int] = [0] * (len(self.bounds) + 1)
        self.n = 0
        self.total = 0.0
        self.vmin: Optional[float] = None
        self.vmax: Optional[float] = None

    def observe(self, v) -> None:
        v = float(v)
        self.n += 1
        self.total += v
        self.vmin = v if self.vmin is None else min(self.vmin, v)
        self.vmax = v if self.vmax is None else max(self.vmax, v)
        self.counts[bisect.bisect_left(self.bounds, v)] += 1

    def percentile(self, q: float) -> Optional[float]:
        if not self.n:
            return None
        target = (q / 100.0) * self.n            # fractional rank
        cum = 0
        for i, c in enumerate(self.counts):
            if not c:
                continue
            if cum + c >= target:
                lo = self.bounds[i - 1] if i > 0 else self.vmin
                hi = self.bounds[i] if i < len(self.bounds) else self.vmax
                val = lo + (hi - lo) * max(target - cum, 0.0) / c
                return float(min(max(val, self.vmin), self.vmax))
            cum += c
        return float(self.vmax)

    @property
    def mean(self) -> Optional[float]:
        return self.total / self.n if self.n else None

    def summary(self) -> Dict:
        return {
            "count": self.n,
            "sum": self.total,
            "min": self.vmin,
            "max": self.vmax,
            "mean": self.mean,
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
        }


class MetricsRegistry:
    """Named metric store: get-or-create accessors, text + JSON export.

    Metrics are keyed on (name, sorted label items); repeated lookups of
    the same key return the same object, so call sites can either hold a
    reference or re-resolve per event — both hit the same cell.  A lock
    guards the registry dicts only (creation); individual updates are
    plain attribute stores, safe under CPython for the single-writer
    engine loop this instruments.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[Tuple[str, _LabelKey], Counter] = {}
        self._gauges: Dict[Tuple[str, _LabelKey], Gauge] = {}
        self._histograms: Dict[Tuple[str, _LabelKey], Histogram] = {}
        self._help: Dict[str, str] = {}

    @property
    def enabled(self) -> bool:
        return True

    def _get(self, store, name, factory, help_, labels):
        key = (name, _label_key(labels))
        metric = store.get(key)
        if metric is None:
            with self._lock:
                metric = store.setdefault(key, factory())
                if help_:
                    self._help.setdefault(name, help_)
        return metric

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get(self._counters, name, Counter, help, labels)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get(self._gauges, name, Gauge, help, labels)

    def histogram(self, name: str, help: str = "",
                  buckets: Tuple[float, ...] = TIME_BUCKETS_US,
                  **labels) -> Histogram:
        return self._get(self._histograms, name,
                         lambda: Histogram(buckets), help, labels)

    # ------------------------------------------------------------- export --
    def snapshot(self) -> Dict:
        """JSON-able view.  Unlabelled metrics key on their bare name;
        labelled ones on ``name{k="v"}`` — so report consumers index the
        common case directly (``snapshot()["histograms"]["ttft_us"]``)."""
        def flat(store, value):
            return {name + _label_str(lk): value(m)
                    for (name, lk), m in sorted(store.items())}

        return {
            "counters": flat(self._counters, lambda m: m.value),
            "gauges": flat(self._gauges, lambda m: m.value),
            "histograms": flat(self._histograms, lambda m: m.summary()),
        }

    def render_text(self) -> str:
        """Prometheus text exposition (counters, gauges, cumulative
        histogram buckets + _sum/_count)."""
        lines: List[str] = []

        def head(name, kind):
            if name in self._help:
                lines.append(f"# HELP {name} {self._help[name]}")
            lines.append(f"# TYPE {name} {kind}")

        seen = set()
        for (name, lk), c in sorted(self._counters.items()):
            if name not in seen:
                head(name, "counter")
                seen.add(name)
            lines.append(f"{name}{_label_str(lk)} {c.value}")
        for (name, lk), g in sorted(self._gauges.items()):
            if name not in seen:
                head(name, "gauge")
                seen.add(name)
            lines.append(f"{name}{_label_str(lk)} {g.value}")
        for (name, lk), h in sorted(self._histograms.items()):
            if name not in seen:
                head(name, "histogram")
                seen.add(name)
            cum = 0
            for bound, c in zip(h.bounds, h.counts):
                cum += c
                le = dict(lk)
                le["le"] = f"{bound:g}"
                lines.append(f"{name}_bucket{_label_str(_label_key(le))} "
                             f"{cum}")
            le = dict(lk)
            le["le"] = "+Inf"
            lines.append(f"{name}_bucket{_label_str(_label_key(le))} {h.n}")
            lines.append(f"{name}_sum{_label_str(lk)} {h.total}")
            lines.append(f"{name}_count{_label_str(lk)} {h.n}")
        return "\n".join(lines) + ("\n" if lines else "")


class _NullMetric:
    """Shared no-op stand-in for counter/gauge/histogram."""

    __slots__ = ()
    value = 0
    n = 0
    total = 0.0
    mean = None

    def inc(self, n=1):
        pass

    def set(self, v):
        pass

    def observe(self, v):
        pass

    def percentile(self, q):
        return None

    def summary(self):
        return {"count": 0, "sum": 0.0, "min": None, "max": None,
                "mean": None, "p50": None, "p95": None, "p99": None}


_NULL_METRIC = _NullMetric()


class NullRegistry:
    """Telemetry-off registry: every accessor returns the shared no-op
    metric, exports are empty.  Call sites never branch on enablement."""

    enabled = False

    def counter(self, name, help="", **labels):
        return _NULL_METRIC

    def gauge(self, name, help="", **labels):
        return _NULL_METRIC

    def histogram(self, name, help="", buckets=TIME_BUCKETS_US, **labels):
        return _NULL_METRIC

    def snapshot(self):
        return {"counters": {}, "gauges": {}, "histograms": {}}

    def render_text(self):
        return ""


NULL_REGISTRY = NullRegistry()

#: process-wide registry for module-level instrumentation that has no
#: engine to hang off (kernels.ops per-backend dispatch counters).  Engine
#: metrics live in per-engine registries so e.g. serve.py's compare-mode
#: engines don't pollute each other.
_GLOBAL = MetricsRegistry()


def global_registry() -> MetricsRegistry:
    return _GLOBAL
