"""Structured trace recorder: ring-buffered span events, Perfetto export.

Records the serving run as Chrome ``trace_event`` JSON — open the saved
file at https://ui.perfetto.dev (or chrome://tracing) and the run renders
as a timeline: one lane ("thread") per request slot showing request
residency segments with their admission prefills, plus an engine lane with
one span per ``step()`` carrying the step's batch composition in its args.

Design constraints, in order:

  * **Near-zero overhead when disabled.**  The disabled path is the
    ``NULL_TRACE`` singleton: every method is a constant-return no-op and
    ``span()`` hands back a reusable null context — no allocation, no
    branching at call sites.
  * **Bounded memory.**  Events land in a fixed-capacity ring; overflow
    overwrites the oldest event and bumps ``dropped`` (exported in the
    trace metadata so a truncated timeline says so).
  * **Monotonic timestamps.**  ``now()`` is microseconds since recorder
    creation from ``time.perf_counter`` — immune to wall-clock steps, and
    the natural unit of the ``ts``/``dur`` fields in the trace_event spec.

Event vocabulary (all standard trace_event phases):

  ``X`` complete span   -- ``complete(name, tid, t0)`` / ``span(...)`` ctx
  ``i`` instant         -- ``instant(name, tid)`` (scope "t")
  ``M`` metadata        -- lane names registered via ``lane(tid, name)``
"""

from __future__ import annotations

import contextlib
import json
import time
from typing import Dict, List, Optional


class TraceRecorder:
    """Fixed-capacity ring of trace_event dicts."""

    def __init__(self, capacity: int = 1 << 16, clock=time.perf_counter):
        assert capacity > 0
        self.capacity = capacity
        self._clock = clock
        self._t0 = clock()
        self._ev: List[Dict] = []
        self._head = 0                      # next overwrite slot when full
        self.dropped = 0
        self._lanes: Dict[tuple, str] = {}  # (pid, tid) -> lane name

    @property
    def enabled(self) -> bool:
        return True

    def now(self) -> float:
        """Microseconds since recorder creation (monotonic)."""
        return (self._clock() - self._t0) * 1e6

    def lane(self, tid: int, name: str, pid: int = 0) -> None:
        """Name a timeline lane (rendered as a thread name in Perfetto)."""
        self._lanes[(pid, tid)] = name

    # ------------------------------------------------------------- record --
    def _push(self, ev: Dict) -> None:
        if len(self._ev) < self.capacity:
            self._ev.append(ev)
        else:
            self._ev[self._head] = ev
            self._head = (self._head + 1) % self.capacity
            self.dropped += 1

    def complete(self, name: str, tid: int, t0: float,
                 t1: Optional[float] = None, pid: int = 0, **args) -> None:
        """A span from t0 to t1 (default: now) on lane `tid`."""
        if t1 is None:
            t1 = self.now()
        ev = {"name": name, "ph": "X", "ts": t0, "dur": max(t1 - t0, 0.0),
              "pid": pid, "tid": tid}
        if args:
            ev["args"] = args
        self._push(ev)

    def instant(self, name: str, tid: int, pid: int = 0, **args) -> None:
        ev = {"name": name, "ph": "i", "s": "t", "ts": self.now(),
              "pid": pid, "tid": tid}
        if args:
            ev["args"] = args
        self._push(ev)

    @contextlib.contextmanager
    def span(self, name: str, tid: int, pid: int = 0, **args):
        t0 = self.now()
        try:
            yield
        finally:
            self.complete(name, tid, t0, pid=pid, **args)

    # ------------------------------------------------------------- export --
    def events(self) -> List[Dict]:
        """Recorded events, oldest first (ring unrolled)."""
        return self._ev[self._head:] + self._ev[:self._head]

    def to_chrome(self) -> Dict:
        """The full Chrome/Perfetto ``trace_event`` JSON object."""
        meta = [{"name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
                 "args": {"name": name}}
                for (pid, tid), name in sorted(self._lanes.items())]
        meta += [{"name": "thread_sort_index", "ph": "M", "pid": pid,
                  "tid": tid, "args": {"sort_index": tid}}
                 for (pid, tid) in sorted(self._lanes)]
        return {
            "traceEvents": meta + self.events(),
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": self.dropped,
                          "capacity": self.capacity},
        }

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)


class NullTrace:
    """Disabled recorder: no-op twin of TraceRecorder (the default — span
    call sites in the engine hot loop cost one attribute lookup and a
    null-context enter/exit)."""

    enabled = False
    dropped = 0
    _NULL_CTX = contextlib.nullcontext()

    def now(self) -> float:
        return 0.0

    def lane(self, tid, name, pid=0):
        pass

    def complete(self, name, tid, t0, t1=None, pid=0, **args):
        pass

    def instant(self, name, tid, pid=0, **args):
        pass

    def span(self, name, tid, pid=0, **args):
        return self._NULL_CTX

    def events(self):
        return []

    def to_chrome(self):
        return {"traceEvents": [], "displayTimeUnit": "ms",
                "otherData": {"dropped_events": 0, "capacity": 0}}

    def save(self, path):
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)


NULL_TRACE = NullTrace()
