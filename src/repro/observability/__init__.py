"""Serving telemetry substrate (dependency-free, host-side only).

Three pillars, one bundle:

  * ``metrics``   -- counters / gauges / bounded-bucket histograms in a
                     ``MetricsRegistry``; Prometheus text exposition +
                     JSON snapshot (merged into engine ``stats()`` and the
                     serve.py report).
  * ``trace``     -- ring-buffered span recorder exporting Chrome/Perfetto
                     ``trace_event`` JSON (``serve --trace-out t.json``):
                     the run as a timeline, one lane per request slot.
  * ``jit_watch`` -- recompile sentinel: jit cache-miss deltas per step
                     function, tagged with the triggering bucket shape;
                     steady-state recompiles are a loud metric and an
                     optional hard failure (``strict``).

``Telemetry`` is the bundle the engine threads through the scheduler and
page pool.  Everything is host-side bookkeeping — no jax imports, nothing
on the traced path — so telemetry on vs off is token-identical by
construction (asserted end-to-end in tests/test_observability.py).
"""

from repro.observability.jit_watch import (  # noqa: F401
    NULL_JIT_WATCH,
    JitWatch,
    NullJitWatch,
    RecompileError,
)
from repro.observability.metrics import (  # noqa: F401
    COUNT_BUCKETS,
    NULL_REGISTRY,
    TIME_BUCKETS_US,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NullRegistry,
    global_registry,
)
from repro.observability.trace import (  # noqa: F401
    NULL_TRACE,
    NullTrace,
    TraceRecorder,
)


class Telemetry:
    """The per-engine telemetry bundle: a metrics registry, a trace
    recorder, and a recompile sentinel, each independently enable-able.

    Defaults are production-shaped: metrics on (cheap host-side updates),
    trace off (enable per run via ``trace=True`` / serve ``--trace-out``),
    sentinel counting but not raising (``strict_recompiles=True`` turns a
    steady-state recompile into an exception — the tests' mode).
    """

    def __init__(self, metrics: bool = True, trace: bool = False,
                 trace_capacity: int = 1 << 16,
                 strict_recompiles: bool = False):
        self.registry = MetricsRegistry() if metrics else NULL_REGISTRY
        self.trace = TraceRecorder(trace_capacity) if trace else NULL_TRACE
        self.jit_watch = (JitWatch(self.registry, strict=strict_recompiles)
                          if metrics else NULL_JIT_WATCH)

    @property
    def enabled(self) -> bool:
        return self.registry.enabled or self.trace.enabled

    @classmethod
    def disabled(cls) -> "Telemetry":
        return cls(metrics=False, trace=False)
