"""Shared nibble pack/unpack layer for the quantized-GEMM kernels.

Two storage layouts for int4 tensors (two values per uint8 byte):

  * interleaved N-packed (``core.quant.pack_int4``): adjacent *columns*
    share a byte.  This is the serialization format (quantized checkpoints,
    ``plan_pack_tree`` serving weights) — compact and axis-generic, but the
    in-kernel unpack needs a stack+reshape interleave, which Mosaic lowers
    as a lane-axis relayout on the matmul critical path.
  * planar K-major (``pack_kmajor``): contraction rows ``k`` and
    ``k + K/2`` share a byte.  The low nibbles of a ``[K/2, N]`` tile *are*
    rows ``[0, K/2)`` and the high nibbles *are* rows ``[K/2, K)`` — the
    in-kernel unpack is a shift/mask with **no relayout**, and the two
    planar halves feed two MXU dots that accumulate into the same tile.

``prepack_kmajor`` converts serialized weights to the kernel layout once
per concrete array (cache keyed by ``id()``, weakref-evicted), so a serving
loop that calls the kernels every step with the same weight pays the
relayout exactly once instead of per call.

This module is self-contained (no repro imports): it is the single home of
the sign-extend / shift-mask helpers that used to be copy-pasted between
``int4_matmul.py`` and ``w4a16_matmul.py``.
"""

from __future__ import annotations

import functools
import weakref
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def pad_to(x: jnp.ndarray, mult: int, axis: int, value=0) -> jnp.ndarray:
    """Zero-pad (or `value`-pad) `axis` of x up to the next multiple of `mult`."""
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def sign_extend_nibble(n: jnp.ndarray) -> jnp.ndarray:
    """Low nibble (two's complement, in [0, 16)) -> int8 in [-8, 7]."""
    return ((n.astype(jnp.int8) ^ 8) - 8).astype(jnp.int8)


def unpack_nibbles(p: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """uint8 -> (lo, hi) sign-extended int8, each the same shape as `p`.

    The shift/mask primitive shared by every kernel; what the nibbles *mean*
    (adjacent columns vs planar row halves) is the caller's layout contract.
    """
    return sign_extend_nibble(p & 0xF), sign_extend_nibble((p >> 4) & 0xF)


def unpack_interleaved(p: jnp.ndarray) -> jnp.ndarray:
    """Interleaved N-packed [..., K, N//2] uint8 -> [..., K, N] int8."""
    lo, hi = unpack_nibbles(p)
    return jnp.stack([lo, hi], axis=-1).reshape(
        *p.shape[:-1], p.shape[-1] * 2)


# ------------------------------------------------------- planar K-major ----
def pack_kmajor(q: jnp.ndarray, row_mult: int = 2) -> jnp.ndarray:
    """[..., K, N] int8 (int4 values) -> [..., K'/2, N] uint8, planar
    (K' = K rounded up to a multiple of `row_mult`, at least even).

    Row r of the packed array holds original row r in its low nibble and
    row r + K'/2 in its high nibble.  Padding rows are zero int4 values and
    contribute nothing to a contraction.  Grouped-scale consumers pass
    ``row_mult=2*group_size`` so each planar half covers whole groups.
    """
    q = pad_to(q, max(2, row_mult), -2)
    half = q.shape[-2] // 2
    lo = q[..., :half, :] & 0xF
    hi = q[..., half:, :] & 0xF
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_kmajor(p: jnp.ndarray) -> jnp.ndarray:
    """Inverse of pack_kmajor: [..., K/2, N] uint8 -> [..., K, N] int8."""
    lo, hi = unpack_nibbles(p)
    return jnp.concatenate([lo, hi], axis=-2)


@functools.partial(jax.jit, static_argnames="row_mult")
def nmajor_to_kmajor(w_packed: jnp.ndarray, row_mult: int = 2) -> jnp.ndarray:
    """Serialized interleaved [..., K, N//2] -> kernel planar [..., K'/2, N]
    (K' = K rounded up to a multiple of `row_mult`, at least even)."""
    return pack_kmajor(unpack_interleaved(w_packed), row_mult)


# ------------------------------------------- per-nibble product tables -----
@functools.lru_cache(maxsize=None)
def nibble_product_tables() -> Tuple[np.ndarray, np.ndarray]:
    """The paper's exact 4x4-bit product table, tiled for GEMM lookup.

    Returns ``(t_lo, t_hi)``, each ``[16, 256]`` int8 host arrays:

        t_lo[a, byte] = sext4(a) * sext4(byte & 0xF)
        t_hi[a, byte] = sext4(a) * sext4(byte >> 4)

    Row index = activation nibble (unsigned 2's-complement code), column
    index = a *packed K-major weight byte* — so a kernel holding packed
    weights never unpacks them: one row-select per activation nibble plus
    one lane-dim take per weight byte reads the sign-extended product
    directly.  Products of int4 values fit int8 (|p| <= 64).  8 KiB total,
    built once per process and shared by every weight tensor.
    """
    s = ((np.arange(16, dtype=np.int32) ^ 8) - 8)          # sext4 of 0..15
    byte = np.arange(256, dtype=np.int32)
    t_lo = s[:, None] * s[byte & 0xF][None, :]
    t_hi = s[:, None] * s[byte >> 4][None, :]
    return t_lo.astype(np.int8), t_hi.astype(np.int8)


@functools.lru_cache(maxsize=None)
def lut4_tables() -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Device-resident ``nibble_product_tables()`` (committed once, cached
    for the life of the process — the \"prepack\" of the LUT backend).

    ``ensure_compile_time_eval`` keeps the cached values concrete even when
    the first call happens under an outer trace (a tracer must never be
    memoized past its trace's lifetime)."""
    t_lo, t_hi = nibble_product_tables()
    with jax.ensure_compile_time_eval():
        return (jax.block_until_ready(jnp.asarray(t_lo)),
                jax.block_until_ready(jnp.asarray(t_hi)))


def table_take(table: jnp.ndarray, rows: jnp.ndarray,
               lanes: jnp.ndarray) -> jnp.ndarray:
    """Two-level vectorized table lookup: ``table[rows[i], lanes[i, j]]``.

    ``rows`` ``[m]`` selects one table row per output row (activation
    nibble); ``lanes`` ``[m, n]`` then takes along the lane dimension
    (packed weight byte).  Both steps are full-width vector ops — no
    per-element one-hot expansion, no scalar gather loop.
    """
    sel = jnp.take(table, rows, axis=0)          # [m, 256]
    return jnp.take_along_axis(sel, lanes, axis=-1)


# ------------------------------------------------- prepacked-weight cache --
# (id(src), row_mult) -> (weakref to src, kmajor-packed array).  The weakref
# callback evicts the entry when the source weight is garbage-collected, so
# the cache never outlives (or pins) the arrays it mirrors.
_PREPACKED: Dict[Tuple[int, int], Tuple[weakref.ref, jnp.ndarray]] = {}


def prepack_kmajor(w_packed: jnp.ndarray, row_mult: int = 2) -> jnp.ndarray:
    """`nmajor_to_kmajor`, cached by array identity for concrete arrays.

    Tracers (calls under an outer jit) convert inline — XLA sees the repack
    as part of the traced graph and CSEs/hoists what it can; concrete
    arrays (eager serving / benchmarks) repack exactly once per weight.
    """
    if isinstance(w_packed, jax.core.Tracer):
        return nmajor_to_kmajor(w_packed, row_mult)
    key = (id(w_packed), row_mult)
    hit = _PREPACKED.get(key)
    if hit is not None and hit[0]() is w_packed:
        return hit[1]
    out = jax.block_until_ready(nmajor_to_kmajor(w_packed, row_mult))
    try:
        ref = weakref.ref(w_packed, lambda _r, _k=key: _PREPACKED.pop(_k, None))
    except TypeError:                      # not weakref-able: skip caching
        return out
    _PREPACKED[key] = (ref, out)
    return out


def prepack_cache_size() -> int:
    return len(_PREPACKED)


def clear_prepack_cache() -> None:
    _PREPACKED.clear()


# ------------------------------------------------------- tile flattening ---
def flatten_to_tiles(x: jnp.ndarray, rows_mult: int, cols: int
                     ) -> Tuple[jnp.ndarray, int]:
    """Flatten any-shape x into a [rows, cols] tile grid, rows padded to a
    multiple of `rows_mult` (single jnp.pad — no O(n) scatter copy).

    Returns (tiles, n) where n is the original element count; undo with
    ``tiles.reshape(-1)[:n].reshape(orig_shape)``.
    """
    n = x.size
    rows = -(-n // cols)
    rows_padded = -(-rows // rows_mult) * rows_mult
    flat = jnp.pad(x.reshape(-1), (0, rows_padded * cols - n))
    return flat.reshape(rows_padded, cols), n
