"""Pallas TPU kernel: weight-only int4 serving matmul (W4A16).

bf16 activations x planar-K-major-packed int4 weights.  This is the
AWQ/GPTQ-shaped deployment mode of the paper's technique: weight bytes drop
4x (the "more multipliers per unit area" argument) while activation precision
is preserved.

The seed kernel dequantized the weight tile to f32 (scale multiply on every
[bk, bn] element) and contracted in f32 — off the fast MXU path.  This
version contracts in the *activation* dtype: int4 values in [-8, 7] are
exactly representable in bf16, so casting the unpacked nibbles to bf16 and
contracting on the bf16 MXU (f32 accumulation) loses nothing, and the scale
multiply moves off the weight tile into the epilogue:

  * per-channel scales [1, N]: one multiply per *output* element, applied
    once at k == nk-1 (a true epilogue — bk x fewer multiplies than
    scaling the weight tile every k-step);
  * per-group scales [K/G, 1, N]: each planar half of a k-step covers whole
    groups (bk % 2G == 0), contracted one group at a time and scaled on the
    [bm, bn] partial product — still O(bm*bn) per group instead of
    O(G*bn) on the weights.

Weights use the planar K-major nibble layout (kernels/packing.py): unpack is
shift/mask only, no relayout; the activation tile is split at K/2 to match.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dispatch import default_interpret
from .packing import pad_to, unpack_nibbles


def _pad_rows(s: jnp.ndarray, rows: int) -> jnp.ndarray:
    """Pad a [g, 1, N] scale slab with zero rows up to exactly `rows`
    (padded K rows hold zero int4 values, so their scale is irrelevant)."""
    return jnp.pad(s, [(0, rows - s.shape[0])] + [(0, 0)] * (s.ndim - 1))


def _dot(x, w_q, cd):
    return jax.lax.dot_general(
        x, w_q.astype(cd), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _kernel_per_channel(xlo_ref, xhi_ref, w_ref, ws_ref, o_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    lo, hi = unpack_nibbles(w_ref[...])          # planar [bk/2, bn] int8
    cd = xlo_ref.dtype
    o_ref[...] += _dot(xlo_ref[...], lo, cd) + _dot(xhi_ref[...], hi, cd)

    @pl.when(k == nk - 1)
    def _epilogue():
        o_ref[...] = o_ref[...] * ws_ref[...]    # [1, bn] per-channel scale


def _kernel_grouped(xlo_ref, xhi_ref, w_ref, slo_ref, shi_ref, o_ref, *,
                    nk: int, gpbh: int, gsize: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    lo, hi = unpack_nibbles(w_ref[...])          # planar [bk/2, bn] int8
    x_lo, x_hi = xlo_ref[...], xhi_ref[...]
    cd = x_lo.dtype
    acc = jnp.zeros_like(o_ref)
    for g in range(gpbh):                        # static unroll: whole groups
        rows = slice(g * gsize, (g + 1) * gsize)
        acc += _dot(x_lo[:, rows], lo[rows], cd) * slo_ref[g]
        acc += _dot(x_hi[:, rows], hi[rows], cd) * shi_ref[g]
    o_ref[...] += acc


@functools.partial(
    jax.jit, static_argnames=("group_size", "bm", "bn", "bk", "interpret")
)
def w4a16_matmul(
    x: jnp.ndarray,            # [M, K] bf16/f32
    w_kmajor: jnp.ndarray,     # [ceil(K/2), N] uint8, planar K-major
    w_scale: jnp.ndarray,      # [K//G, 1, N] f32 (or [1, N] per-channel)
    group_size: int,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool = None,
) -> jnp.ndarray:
    M, K = x.shape
    N = w_kmajor.shape[1]
    Keven = w_kmajor.shape[0] * 2
    per_channel = w_scale.ndim == 2
    # packing may have padded K (odd K, or grouped row_mult alignment)
    assert K <= Keven <= K + (1 if per_channel else 2 * group_size), \
        (x.shape, w_kmajor.shape, group_size)
    # compute dtype: bf16 stays bf16 (MXU path, int4 exact); f32 stays f32
    cd = x.dtype if x.dtype == jnp.bfloat16 else jnp.float32
    x = pad_to(x.astype(cd), Keven, 1)
    K2 = Keven // 2

    if per_channel:
        assert bk % 2 == 0, bk
        bkh = bk // 2
    else:
        G = group_size
        assert Keven % (2 * G) == 0, (K, G)      # groups align to the halves
        bkh = bk // 2
        if bkh % G:                              # self-heal invalid tile
            bkh = max(G, -(-bkh // G) * G)
        gpbh = bkh // G

    x_lo = pad_to(pad_to(x[:, :K2], bm, 0), bkh, 1)
    x_hi = pad_to(pad_to(x[:, K2:], bm, 0), bkh, 1)
    w_kmajor = pad_to(pad_to(w_kmajor, bkh, 0), bn, 1)
    Mp = x_lo.shape[0]
    Np = w_kmajor.shape[1]
    nk = x_lo.shape[1] // bkh
    interpret = default_interpret(interpret)
    x_specs = [
        pl.BlockSpec((bm, bkh), lambda i, j, k: (i, k)),
        pl.BlockSpec((bm, bkh), lambda i, j, k: (i, k)),
        pl.BlockSpec((bkh, bn), lambda i, j, k: (k, j)),
    ]

    if per_channel:
        out = pl.pallas_call(
            functools.partial(_kernel_per_channel, nk=nk),
            grid=(Mp // bm, Np // bn, nk),
            in_specs=x_specs + [pl.BlockSpec((1, bn), lambda i, j, k: (0, j))],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
            interpret=interpret,
        )(x_lo, x_hi, w_kmajor, pad_to(w_scale, bn, 1))
    else:
        ng2 = (Keven // G) // 2                  # groups per planar half
        rows = x_lo.shape[1] // G                # scale rows the grid reads
        s_lo = pad_to(_pad_rows(w_scale[:ng2], rows), bn, 2)
        s_hi = pad_to(_pad_rows(w_scale[ng2:], rows), bn, 2)
        out = pl.pallas_call(
            functools.partial(_kernel_grouped, nk=nk, gpbh=gpbh, gsize=G),
            grid=(Mp // bm, Np // bn, nk),
            in_specs=x_specs + [
                pl.BlockSpec((gpbh, 1, bn), lambda i, j, k: (k, 0, j)),
                pl.BlockSpec((gpbh, 1, bn), lambda i, j, k: (k, 0, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
            interpret=interpret,
        )(x_lo, x_hi, w_kmajor, s_lo, s_hi)
    return out[:M, :N]
