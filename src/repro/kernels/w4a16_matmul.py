"""Pallas TPU kernel: weight-only int4 serving matmul (W4A16).

bf16 activations x packed-int4 weights with per-group scales, dequantized
tile-by-tile in VMEM and contracted on the bf16 MXU with f32 accumulation.
This is the AWQ/GPTQ-shaped deployment mode of the paper's technique: weight
bytes drop 4x (the "more multipliers per unit area" argument) while activation
precision is preserved.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .int4_matmul import _pad_to


def _kernel(x_ref, w_ref, ws_ref, o_ref, *, nk: int, groups_per_bk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]                                           # [bm, bk] bf16
    wp = w_ref[...]                                          # [bk, bn//2] uint8
    lo = ((wp & 0xF) ^ 8).astype(jnp.int8) - 8
    hi = (((wp >> 4) & 0xF) ^ 8).astype(jnp.int8) - 8
    w_q = jnp.stack([lo, hi], axis=-1).reshape(wp.shape[0], wp.shape[1] * 2)
    bk, bn = w_q.shape
    scale = ws_ref[...]                                      # [groups_per_bk, 1, bn]
    w = (
        w_q.reshape(groups_per_bk, bk // groups_per_bk, bn).astype(jnp.float32)
        * scale
    ).reshape(bk, bn)
    acc = jax.lax.dot_general(
        x.astype(jnp.float32), w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] += acc


@functools.partial(
    jax.jit, static_argnames=("group_size", "bm", "bn", "bk", "interpret")
)
def w4a16_matmul(
    x: jnp.ndarray,            # [M, K] bf16/f32
    w_packed: jnp.ndarray,     # [K, N//2] uint8
    w_scale: jnp.ndarray,      # [K//G, 1, N] f32
    group_size: int,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool = None,
) -> jnp.ndarray:
    M, K = x.shape
    N = w_packed.shape[1] * 2
    assert K % group_size == 0 and bk % group_size == 0, (K, bk, group_size)
    if w_scale.ndim == 2:                                    # per-channel
        w_scale = w_scale.reshape(1, 1, N)
        group_size = K
        assert bk % K == 0 or K % bk == 0
        gpb = max(1, bk // K)
    else:
        gpb = bk // group_size

    x = _pad_to(_pad_to(x, bm, 0), bk, 1)
    w_packed = _pad_to(_pad_to(w_packed, bk, 0), bn // 2, 1)
    w_scale = _pad_to(_pad_to(w_scale, gpb, 0), bn, 2)
    Mp, Kp = x.shape
    Np = w_packed.shape[1] * 2
    nk = Kp // bk

    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk, groups_per_bk=gpb),
        grid=(Mp // bm, Np // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn // 2), lambda i, j, k: (k, j)),
            pl.BlockSpec((gpb, 1, bn), lambda i, j, k: (k, 0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        interpret=(jax.default_backend() != "tpu"
                   if interpret is None else interpret),
    )(x, w_packed, w_scale)
    return out[:M, :N]
