"""Fused paged-attention Pallas kernels: decode that reads KV pages in
place, and a tiled flash prefill.

The serving hot path used to be gather-then-attend: every decode step
``paged_read`` materialized a sequence's whole KV history out of the page
pool into a dense ``[B, max_ctx, KV, hd]`` buffer before attention ran, so
per-step memory traffic was ~3x the KV bytes (read pool + write dense +
re-read dense for QK and PV) and always paid for ``max_ctx`` slots no
matter how short the actual context.  This module is the paper's roofline
argument applied to serving: the multiplier only wins once the surrounding
data movement is gone, so attention must consume the pages where they live.

``paged_decode_attention``
    One program per (batch row, KV-head tile); the block table rides in as
    a scalar-prefetch operand so the BlockSpec index_map fetches *physical*
    pages straight from the pool — no gather, no dense intermediate.  Each
    program walks its row's logical pages ``pages_per_program`` at a time
    with flash-style online-softmax accumulation in VMEM scratch; slots past
    ``last_pos`` (and fully inactive rows, ``last_pos == -1``) are masked
    in-kernel.  int8/int4 pools dequantize per fetched page with the same
    ``q * scale -> bf16`` rounding as ``serving.kv_pages`` gather path.

``flash_prefill``
    Tiled causal attention over the in-flight prompt: grid over
    (batch, head tile, q tile, kv tile) with online-softmax scratch carried
    across the kv dimension — scores only ever exist as ``[bq, bk]`` tiles,
    never as the ``[S, S]`` matrix the chunked path builds per chunk.

Numerics: QK products are rounded to the compute dtype before the f32
softmax when activations are bf16 — exactly the rounding the dense
reference path gets from its bf16 einsum.  The Pallas decode kernel runs
classic single-pass online softmax (f32 PV accumulation; bf16-tolerance vs
the gather path — the right trade on TPU, where a second pool sweep costs
real HBM bandwidth).  Its XLA twin ``paged_decode_attention_xla`` — the
path CPU/GPU hosts execute, and the one the `--layout compare` harness
gates — instead does two blocked passes (scores buffer, then the *exact*
dense softmax + probs cast, then blocked PV), which makes it bit-identical
to the gather reference for bf16/int8/int4 pools while still never
materializing the dense KV layout and stopping at the last active page.

``kernels.ops`` picks Mosaic vs interpreter vs twin the same way it does
for the GEMM kernels.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .dispatch import default_interpret
from .packing import unpack_nibbles

NEG_INF = -1e30


def _largest_divisor(n: int, bound: int) -> int:
    """Largest divisor of n that is <= bound (self-heal head tiles)."""
    b = max(1, min(bound, n))
    while n % b:
        b -= 1
    return b


def _dequant_slab(kq, scale, hd: int):
    """Pool slab [..., hd or hd//2] -> bf16, matching kv_pages'
    ``dequantize_kv`` rounding exactly (int4 nibbles interleave along hd)."""
    if kq.dtype == jnp.uint8:                      # packed int4 pairs
        lo, hi = unpack_nibbles(kq)
        kq = jnp.stack([lo, hi], axis=-1).reshape(*kq.shape[:-1], hd)
    if kq.dtype == jnp.int8:
        return (kq.astype(jnp.float32) * scale).astype(jnp.bfloat16)
    return kq                                      # float pool: passthrough


def _round_scores(s, compute_dtype):
    """f32-accumulated QK tile -> the dense path's score values: bf16
    activations round the einsum result to bf16 before the f32 softmax."""
    if compute_dtype == jnp.bfloat16:
        s = s.astype(jnp.bfloat16)
    return s.astype(jnp.float32)


# ------------------------------------------------------- decode (paged) ----
def _decode_kernel(tbl_ref, lp_ref, q_ref, *refs, pp: int, ps: int, nj: int,
                   G: int, bkv: int, hd: int, window: int, quant: bool,
                   scale: float):
    k_refs = refs[:pp]
    v_refs = refs[pp:2 * pp]
    i = 2 * pp
    if quant:
        ks_refs = refs[i:i + pp]
        vs_refs = refs[i + pp:i + 2 * pp]
        i += 2 * pp
    o_ref, acc_ref, m_ref, l_ref = refs[i:i + 4]

    b, j = pl.program_id(0), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    lp = lp_ref[b]
    cd = q_ref.dtype
    qh = q_ref[0].reshape(bkv, G, hd)              # [bkv, G, hd]

    for u in range(pp):                            # static unroll: pages
        kb = k_refs[u][0]                          # [ps, bkv, hd(/2)]
        vb = v_refs[u][0]
        if quant:
            kb = _dequant_slab(kb, ks_refs[u][0], hd)
            vb = _dequant_slab(vb, vs_refs[u][0], hd)
        # scores [bkv, G, ps]: batch over kv heads, contract hd
        s = jax.lax.dot_general(
            qh, kb.transpose(1, 0, 2).astype(cd),
            (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        s = _round_scores(s, cd) * scale

        logical = j * pp + u
        pos = logical * ps + jax.lax.broadcasted_iota(
            jnp.int32, (1, 1, ps), 2)
        mask = (pos <= lp) & (lp >= 0)
        if window:
            mask &= (lp - pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)   # explicit zero: an
        # all-masked prefix keeps m at NEG_INF and exp(0)=1 would otherwise
        # leak the masked slots into l/acc
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            p, vb.transpose(1, 0, 2).astype(jnp.float32),
            (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)        # [bkv, G, hd]
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    @pl.when(j == nj - 1)
    def _emit():
        l = l_ref[...]
        out = acc_ref[...] / jnp.where(l > 0, l, 1.0)  # inactive row -> 0
        o_ref[...] = out.reshape(1, bkv * G, hd).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "pp", "bkv", "interpret"))
def paged_decode_attention(
    q: jnp.ndarray,            # [B, H, hd]
    k_pool: jnp.ndarray,       # [P, ps, KV, hd]  (uint8: [..., hd//2])
    v_pool: jnp.ndarray,
    tbl: jnp.ndarray,          # [B, pages_per_seq] int32
    last_pos: jnp.ndarray,     # [B] int32, newest valid position (-1 = idle)
    k_scale: jnp.ndarray = None,   # [P, ps, KV, 1] f32 when quantized
    v_scale: jnp.ndarray = None,
    window: int = 0,
    pp: int = 4,               # pages per program (autotuned: attn.paged_decode)
    bkv: int = 0,              # KV-head tile, 0 = all heads
    interpret: bool = None,
) -> jnp.ndarray:
    B, H, hd = q.shape
    P, ps, KV = k_pool.shape[:3]
    pps = tbl.shape[1]
    assert H % KV == 0, (H, KV)           # query heads tile evenly over KV heads
    G = H // KV
    quant = k_scale is not None

    bkv = _largest_divisor(KV, bkv if bkv > 0 else KV)
    assert KV % bkv == 0, (KV, bkv)       # _largest_divisor contract
    pp = max(1, min(pp, pps))
    nj = -(-pps // pp)
    nh = KV // bkv
    interpret = default_interpret(interpret)

    tbl = tbl.astype(jnp.int32)
    last_pos = last_pos.astype(jnp.int32)

    def page_spec(u, heads):
        # the scalar-prefetched block table turns the logical page into a
        # physical pool index right in the index_map: the pipeline DMAs the
        # page from wherever it lives, no gather ever materializes.  Dead
        # table slots carry the out-of-bounds sentinel (== P); clamp so the
        # DMA stays in bounds — the kernel masks those positions anyway.
        def index(b, h, j, tbl_ref, lp_ref):
            logical = jnp.minimum(j * pp + u, pps - 1)
            return (jnp.minimum(tbl_ref[b, logical], P - 1), 0,
                    h if heads else 0, 0)
        return index

    kv_block = k_pool.shape[-1]                    # hd, or hd//2 packed
    in_specs = [pl.BlockSpec((1, bkv * G, hd), lambda b, h, j, t, l: (b, h, 0))]
    in_specs += [pl.BlockSpec((1, ps, bkv, kv_block), page_spec(u, True))
                 for u in range(pp)]
    in_specs += [pl.BlockSpec((1, ps, bkv, kv_block), page_spec(u, True))
                 for u in range(pp)]
    args = [q, *([k_pool] * pp), *([v_pool] * pp)]
    if quant:
        in_specs += [pl.BlockSpec((1, ps, bkv, 1), page_spec(u, True))
                     for u in range(pp)]
        in_specs += [pl.BlockSpec((1, ps, bkv, 1), page_spec(u, True))
                     for u in range(pp)]
        args += [*([k_scale] * pp), *([v_scale] * pp)]

    kernel = functools.partial(
        _decode_kernel, pp=pp, ps=ps, nj=nj, G=G, bkv=bkv, hd=hd,
        window=window, quant=quant, scale=1.0 / math.sqrt(hd))
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, nh, nj),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, bkv * G, hd),
                                   lambda b, h, j, t, l: (b, h, 0)),
            scratch_shapes=[
                pltpu.VMEM((bkv, G, hd), jnp.float32),
                pltpu.VMEM((bkv, G, 1), jnp.float32),
                pltpu.VMEM((bkv, G, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        interpret=interpret,
    )(tbl, last_pos, *args)
    return out


@functools.partial(jax.jit, static_argnames=("window", "pp"))
def paged_decode_attention_xla(
    q, k_pool, v_pool, tbl, last_pos, k_scale=None, v_scale=None,
    window: int = 0, pp: int = 4,
) -> jnp.ndarray:
    """Pure-XLA twin, *bit-identical to the gather reference* by
    construction: two dynamic-trip-count passes over page blocks —

      1. blocked QK into a [B, KV, G, max_ctx] f32 score buffer (scores are
         tiny: no hd factor, ~1/2*hd the bytes of the dense KV gather),
      2. the exact softmax + probs->compute-dtype cast the dense path runs
         on its materialized scores,
      3. blocked PV with f32 partial accumulation.

    Both loops stop at the last *active* page in the batch, so per-step
    work scales with the actual context, not the pool bound, and the dense
    [B, max_ctx, KV, hd] K/V buffers never exist.  This is what keeps the
    `--layout compare` harness token-identical across contiguous,
    paged-gather, and paged-fused on CPU hosts."""
    B, H, hd = q.shape
    P, ps, KV = k_pool.shape[:3]
    pps = tbl.shape[1]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    cd = q.dtype
    quant = k_scale is not None

    pp = max(1, min(pp, pps))
    nj = -(-pps // pp)
    tokens = pp * ps
    S = nj * tokens
    # pad the table so each block slices pp whole columns; padded columns
    # carry the out-of-bounds sentinel like dead slots do — their positions
    # are past last_pos, so their (clamped-gather) data masks away through
    # zero probs in the PV loop
    tbl_p = jnp.pad(tbl.astype(jnp.int32), ((0, 0), (0, nj * pp - pps)),
                    constant_values=P)
    last_pos = last_pos.astype(jnp.int32)
    q4 = q.reshape(B, KV, G, hd)
    steps = jnp.clip((jnp.max(last_pos) + tokens) // tokens, 1, nj)

    def qk_body(carry):
        j, sbuf = carry
        cols = jax.lax.dynamic_slice_in_dim(tbl_p, j * pp, pp, 1)  # [B, pp]
        kb = k_pool[cols]                          # [B, pp, ps, KV, hd(/2)]
        if quant:
            kb = _dequant_slab(kb, k_scale[cols], hd)
        kb = kb.reshape(B, tokens, KV, hd)
        s = jnp.einsum("bkgh,btkh->bkgt", q4, kb.astype(cd))
        s = _round_scores(s, cd) * scale           # [B, KV, G, tokens]
        return j + 1, jax.lax.dynamic_update_slice(
            sbuf, s, (0, 0, 0, j * tokens))

    _, sbuf = jax.lax.while_loop(
        lambda c: c[0] < steps, qk_body,
        (jnp.zeros((), jnp.int32),
         jnp.full((B, KV, G, S), NEG_INF, jnp.float32)))

    pos = jnp.arange(S, dtype=jnp.int32)
    mask = (pos[None, :] <= last_pos[:, None]) & (last_pos >= 0)[:, None]
    if window:
        mask &= (last_pos[:, None] - pos[None, :]) < window
    sbuf = jnp.where(mask[:, None, None, :], sbuf, NEG_INF)
    probs = jax.nn.softmax(sbuf, axis=-1).astype(
        jnp.bfloat16 if quant else v_pool.dtype)

    def pv_body(carry):
        j, acc = carry
        cols = jax.lax.dynamic_slice_in_dim(tbl_p, j * pp, pp, 1)
        vb = v_pool[cols]
        if quant:
            vb = _dequant_slab(vb, v_scale[cols], hd)
        vb = vb.reshape(B, tokens, KV, hd)
        # dead table slots hold the out-of-bounds sentinel (== P); the
        # gather clamps them to the last physical page, whose masked
        # positions contribute exactly 0 via zero probs.  (Finite-garbage
        # safe, like the pre-sentinel code; the NaN-proof zero-fill lives
        # in paged_read — zeroing V per block here costs 10-25% of the
        # decode step for a hazard only a NaN-poisoned pool can hit.)
        p = jax.lax.dynamic_slice_in_dim(probs, j * tokens, tokens, 3)
        pv = jnp.einsum("bkgt,btkh->bkgh", p, vb,
                        preferred_element_type=jnp.float32)
        return j + 1, acc + pv

    _, acc = jax.lax.while_loop(
        lambda c: c[0] < steps, pv_body,
        (jnp.zeros((), jnp.int32), jnp.zeros((B, KV, G, hd), jnp.float32)))
    # fully-masked rows see a uniform softmax over NEG_INF scores; zero them
    # explicitly (the kernel's l>0 guard does the same) — their output is
    # discarded but must stay finite and deterministic
    acc *= (last_pos >= 0)[:, None, None, None]
    return acc.reshape(B, H, hd).astype(q.dtype)


# ------------------------------------------------------- prefill (flash) ----
def _prefill_kernel(q_ref, k_ref, v_ref, qp_ref, kp_ref, o_ref,
                    acc_ref, m_ref, l_ref, *, nk: int, G: int, bkv: int,
                    hd: int, window: int, scale: float):
    kk = pl.program_id(3)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    cd = q_ref.dtype
    bq = q_ref.shape[1]
    bk = k_ref.shape[1]
    # [bq, bkv*G, hd] -> [bkv, G*bq, hd] so kv heads batch the MXU dots
    qh = (q_ref[0].reshape(bq, bkv, G, hd).transpose(1, 2, 0, 3)
          .reshape(bkv, G * bq, hd))
    kb = k_ref[0].transpose(1, 0, 2)               # [bkv, bk, hd]
    s = jax.lax.dot_general(
        qh, kb.astype(cd), (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)
    s = (_round_scores(s, cd) * scale).reshape(bkv, G, bq, bk)

    qp, kp = qp_ref[0], kp_ref[0]                  # [bq], [bk]
    mask = (qp[:, None] >= kp[None, :]) & (kp[None, :] >= 0)
    if window:
        mask &= (qp[:, None] - kp[None, :]) < window
    mask = mask[None, None]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(
        p.reshape(bkv, G * bq, bk),
        v_ref[0].transpose(1, 0, 2).astype(jnp.float32),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32).reshape(bkv, G, bq, hd)
    acc_ref[...] = acc_ref[...] * alpha + pv
    m_ref[...] = m_new

    @pl.when(kk == nk - 1)
    def _emit():
        l = l_ref[...]
        out = acc_ref[...] / jnp.where(l > 0, l, 1.0)
        o_ref[...] = (out.transpose(2, 0, 1, 3)
                      .reshape(1, bq, bkv * G, hd).astype(o_ref.dtype))


@functools.partial(
    jax.jit, static_argnames=("window", "bq", "bk", "bkv", "interpret"))
def flash_prefill(
    q: jnp.ndarray,            # [B, Sq, H, hd]
    k: jnp.ndarray,            # [B, Skv, KV, hd]
    v: jnp.ndarray,
    q_positions: jnp.ndarray,  # [B, Sq] int32 (-1 = pad)
    k_positions: jnp.ndarray,  # [B, Skv] int32 (-1 = pad)
    window: int = 0,
    bq: int = 128,
    bk: int = 128,
    bkv: int = 0,
    interpret: bool = None,
) -> jnp.ndarray:
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    assert H % KV == 0, (H, KV)           # query heads tile evenly over KV heads
    G = H // KV
    bq = min(bq, max(8, Sq))
    bk = min(bk, max(8, k.shape[1]))
    bkv = _largest_divisor(KV, bkv if bkv > 0 else KV)
    assert KV % bkv == 0, (KV, bkv)       # _largest_divisor contract
    interpret = default_interpret(interpret)

    def padq(x, value=0):
        pad = (-x.shape[1]) % bq
        widths = [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2)
        return jnp.pad(x, widths, constant_values=value) if pad else x

    def padk(x, value=0):
        pad = (-x.shape[1]) % bk
        widths = [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2)
        return jnp.pad(x, widths, constant_values=value) if pad else x

    qp = padq(q)
    kp_, vp_ = padk(k), padk(v)
    qpos = padq(q_positions.astype(jnp.int32), value=-1)
    kpos = padk(k_positions.astype(jnp.int32), value=-1)
    nq, nk = qp.shape[1] // bq, kp_.shape[1] // bk
    nh = KV // bkv

    kernel = functools.partial(
        _prefill_kernel, nk=nk, G=G, bkv=bkv, hd=hd, window=window,
        scale=1.0 / math.sqrt(hd))
    out = pl.pallas_call(
        kernel,
        grid=(B, nh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, bkv * G, hd),
                         lambda b, h, i, kk: (b, i, h, 0)),
            pl.BlockSpec((1, bk, bkv, hd), lambda b, h, i, kk: (b, kk, h, 0)),
            pl.BlockSpec((1, bk, bkv, hd), lambda b, h, i, kk: (b, kk, h, 0)),
            pl.BlockSpec((1, bq), lambda b, h, i, kk: (b, i)),
            pl.BlockSpec((1, bk), lambda b, h, i, kk: (b, kk)),
        ],
        out_specs=pl.BlockSpec((1, bq, bkv * G, hd),
                               lambda b, h, i, kk: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bkv, G, bq, hd), jnp.float32),
            pltpu.VMEM((bkv, G, bq, 1), jnp.float32),
            pltpu.VMEM((bkv, G, bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp_, vp_, qpos, kpos)
    return out[:, :Sq]


@functools.partial(jax.jit, static_argnames=("window", "bk"))
def flash_prefill_xla(
    q, k, v, q_positions, k_positions, window: int = 0, bk: int = 128,
) -> jnp.ndarray:
    """Pure-XLA twin: lax.scan over kv tiles with the same online-softmax
    carry — peak score memory is [B, KV, G, Sq, bk], never [Sq, Skv]."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    cd = q.dtype
    bk = min(bk, max(8, k.shape[1]))

    pad = (-k.shape[1]) % bk
    if pad:
        widths = ((0, 0), (0, pad), (0, 0), (0, 0))
        k = jnp.pad(k, widths)
        v = jnp.pad(v, widths)
        k_positions = jnp.pad(k_positions, ((0, 0), (0, pad)),
                              constant_values=-1)
    nk = k.shape[1] // bk
    qg = q.reshape(B, Sq, KV, G, hd)
    qpos = q_positions.astype(jnp.int32)

    def tiles(x):
        return jnp.moveaxis(
            x.reshape(B, nk, bk, *x.shape[2:]), 1, 0)  # [nk, B, bk, ...]

    def step(carry, xs):
        m, l, acc = carry
        kb, vb, kposb = xs
        s = jnp.einsum("bqkgh,btkh->bkgqt", qg, kb.astype(cd))
        s = _round_scores(s, cd) * scale
        mask = (qpos[:, :, None] >= kposb[:, None, :]) \
            & (kposb[:, None, :] >= 0)
        if window:
            mask &= (qpos[:, :, None] - kposb[:, None, :]) < window
        mask = mask[:, None, None]                 # [B, 1, 1, Sq, bk]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        pv = jnp.einsum("bkgqt,btkh->bkgqh", p, vb.astype(jnp.float32))
        return (m_new, l, alpha * acc + pv), None

    init = (jnp.full((B, KV, G, Sq, 1), NEG_INF, jnp.float32),
            jnp.zeros((B, KV, G, Sq, 1), jnp.float32),
            jnp.zeros((B, KV, G, Sq, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(
        step, init, (tiles(k), tiles(v), tiles(k_positions.astype(jnp.int32))))
    out = acc / jnp.where(l > 0, l, 1.0)           # [B, KV, G, Sq, hd]
    return (out.transpose(0, 3, 1, 2, 4)
            .reshape(B, Sq, H, hd).astype(q.dtype))
