"""Pallas TPU kernel: table-lookup W4A4 GEMM — the paper's LUT multiplier
amortized across a matmul tile.

The paper replaces the FPGA's partial-product array with a 4x4-bit lookup
table in LUT6 primitives.  The elementwise port (`kernels/lut_mul4.py`)
evaluates that table per scalar product — a 256-wide one-hot contraction or a
flat gather — and lands ~300x behind the int8 reference because every product
pays the full table-evaluation latency.  LUTMUL's observation (PAPERS.md) is
that the lookup cost amortizes when one table row is reused across a GEMM
tile; this kernel is that shape on the VPU:

  * host side, once per process: the 16x256 per-nibble partial-product tables
    (`packing.nibble_product_tables`) — row = activation nibble, column = a
    *packed* K-major weight byte, entry = the sign-extended int8 product.
    Weights therefore stay packed end-to-end: the tables fold sign-extend,
    multiply, and nibble-select into one read.
  * in-kernel, per contraction row: one row-select `take` (activation nibble
    picks the table row) plus one lane-dim `take_along_axis` (the [bkh, bn]
    packed weight byte picks the lane slice) — `packing.table_take`.  Both
    are full-width vector ops: no per-element one-hot, no scalar gather loop.
  * accumulation is int32 adds on the VPU (MXU-free), with the dequant scales
    folded into the epilogue exactly like `w4a16_matmul`.

  grid (M/bm, N/bn, K/bk), K innermost:
    k == 0     : zero the accumulator tile
    every k    : fori_loop over the bk/2 packed rows; two table_take lookups
                 (lo/hi planar halves) per row, int32 accumulate
    k == K-1   : fused dequant epilogue  out *= a_scale[m] * w_scale[n]

Bit-exactness: the exact product table is rank-1 (T[a, w] = a*w), so the
lookup-sum is the same integer as the int8 dot; int32 accumulation is exact
and |acc| < 2^24 keeps the f32 carry exact, so this kernel is bitwise
identical to `int4_matmul` and to the XLA twin in `ops.lut4_matmul`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dispatch import default_interpret
from .packing import lut4_tables, pad_to, table_take


def _kernel(alo_ref, ahi_ref, w_ref, tlo_ref, thi_ref, as_ref, ws_ref, o_ref,
            *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Unsigned nibble codes index the table rows; sign lives in the entries.
    u_lo = (alo_ref[...] & 0xF).astype(jnp.int32)   # [bm, bkh]
    u_hi = (ahi_ref[...] & 0xF).astype(jnp.int32)
    wb = w_ref[...].astype(jnp.int32)               # [bkh, bn] packed bytes
    t_lo = tlo_ref[...]                             # [16(+pad), 256] int8
    t_hi = thi_ref[...]
    bm, bkh = u_lo.shape
    bn = wb.shape[1]

    def body(kh, acc):
        rows_lo = jax.lax.dynamic_slice(u_lo, (0, kh), (bm, 1))[:, 0]
        rows_hi = jax.lax.dynamic_slice(u_hi, (0, kh), (bm, 1))[:, 0]
        lanes = jnp.broadcast_to(
            jax.lax.dynamic_slice(wb, (kh, 0), (1, bn)), (bm, bn))
        acc += table_take(t_lo, rows_lo, lanes).astype(jnp.int32)
        acc += table_take(t_hi, rows_hi, lanes).astype(jnp.int32)
        return acc

    acc = jax.lax.fori_loop(0, bkh, body, jnp.zeros((bm, bn), jnp.int32))
    o_ref[...] += acc.astype(jnp.float32)           # exact: |acc| < 2^24

    @pl.when(k == nk - 1)
    def _epilogue():
        o_ref[...] = o_ref[...] * as_ref[...] * ws_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def lut4_matmul(
    a_q: jnp.ndarray,          # [M, K] int8 holding int4 values
    a_scale: jnp.ndarray,      # [M, 1] f32
    w_kmajor: jnp.ndarray,     # [ceil(K/2), N] uint8, planar K-major
    w_scale: jnp.ndarray,      # [1, N] f32
    bm: int = 128,
    bn: int = 128,
    bk: int = 256,
    interpret: bool = None,
) -> jnp.ndarray:
    M, K = a_q.shape
    N = w_kmajor.shape[1]
    Keven = w_kmajor.shape[0] * 2
    assert Keven in (K, K + 1), (a_q.shape, w_kmajor.shape)
    a = pad_to(a_q, Keven, 1)               # odd K: one zero column
    assert bk % 2 == 0, bk
    bkh = bk // 2

    # Zero padding is absorbing through the tables: nibble code 0 selects the
    # all-zero table row, and weight byte 0 selects an all-zero lane pair.
    K2 = Keven // 2
    a_lo = pad_to(pad_to(a[:, :K2], bm, 0), bkh, 1)
    a_hi = pad_to(pad_to(a[:, K2:], bm, 0), bkh, 1)
    a_scale = pad_to(a_scale, bm, 0, value=1)
    w_kmajor = pad_to(pad_to(w_kmajor, bkh, 0), bn, 1)
    w_scale = pad_to(w_scale, bn, 1)
    Mp = a_lo.shape[0]
    Np = w_kmajor.shape[1]
    nk = a_lo.shape[1] // bkh

    # Pad table rows 16 -> 32 so the block meets the int8 (32, 128) min tile.
    t_lo, t_hi = (pad_to(t, 32, 0) for t in lut4_tables())

    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=(Mp // bm, Np // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bkh), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, bkh), lambda i, j, k: (i, k)),
            pl.BlockSpec((bkh, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((32, 256), lambda i, j, k: (0, 0)),
            pl.BlockSpec((32, 256), lambda i, j, k: (0, 0)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        interpret=default_interpret(interpret),
    )(a_lo, a_hi, w_kmajor, t_lo, t_hi, a_scale, w_scale)
    return out[:M, :N]
