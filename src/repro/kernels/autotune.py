"""Block-size (bm, bn, bk) autotuner for the quantized-GEMM Pallas kernels.

The paper's FPGA argument — the same exact multiplier, specialized to the
fabric — translates on TPU to tile shapes specialized per deployment GEMM
shape.  This module owns that specialization:

  * ``get_blocks(op, M, K, N, ...)`` — the lookup every call site (qdense,
    and through it models/ffn.py, models/attention.py and the serving
    engine) goes through instead of hard-coded tiles.  Returns the tuned
    entry when one exists, else a shape-clipped heuristic default.  Never
    triggers a search by itself: lookups happen inside jit traces and must
    stay cheap and deterministic.
  * ``tune(...)`` — the timed search.  Run explicitly (``benchmarks/run.py
    kernels`` on a TPU host, or ``REPRO_AUTOTUNE=1``); results persist to an
    on-disk JSON cache keyed by (op, shape, dtype, group size, backend).

Cache location: ``$REPRO_AUTOTUNE_CACHE`` if set, else
``~/.cache/repro/autotune.json``.
"""

from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, Iterable, List, Optional, Tuple

import jax
import numpy as np

from repro.observability.metrics import global_registry

ENV_CACHE_PATH = "REPRO_AUTOTUNE_CACHE"
ENV_AUTOTUNE = "REPRO_AUTOTUNE"

# What a rejected candidate tile may legitimately raise: bad block/grid
# shapes (ValueError, or AssertionError from the wrappers' divisibility
# contracts), a kernel with no lowering on this backend
# (NotImplementedError), or an XLA compile/runtime failure.  The tuner
# skips these; real programming errors propagate.
_TILE_REJECT_ERRORS = (ValueError, AssertionError, NotImplementedError,
                       jax.errors.JaxRuntimeError)

# in-memory mirror of the on-disk cache: key -> {"bm","bn","bk","us"}
_CACHE: Dict[str, Dict] = {}
_LOADED_FROM: Optional[str] = None


def cache_path() -> str:
    env = os.environ.get(ENV_CACHE_PATH)
    if env:
        return os.path.expanduser(env)
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "autotune.json")


def cache_key(op: str, M: int, K: int, N: int, dtype: str,
              group_size: int = 0, backend: str = "", tag: str = "") -> str:
    backend = backend or jax.default_backend()
    key = f"{op}|m{M}|k{K}|n{N}|{dtype}|g{group_size}|{backend}"
    return f"{key}|{tag}" if tag else key


def reset() -> None:
    """Drop in-memory state (tests; cache file is untouched)."""
    global _LOADED_FROM
    _CACHE.clear()
    _LOADED_FROM = None


def load_cache(path: Optional[str] = None) -> int:
    """Merge the on-disk cache into memory; returns #entries loaded.
    A missing or corrupt file is an empty cache, never an error."""
    global _LOADED_FROM
    path = path or cache_path()
    _LOADED_FROM = path
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, ValueError):
        return 0
    if not isinstance(data, dict):
        return 0
    n = 0
    for key, entry in data.items():
        if isinstance(entry, dict) and {"bm", "bn", "bk"} <= set(entry):
            _CACHE[key] = entry
            n += 1
    return n


def save_cache(path: Optional[str] = None) -> str:
    path = path or cache_path()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(_CACHE, f, indent=1, sort_keys=True)
    os.replace(tmp, path)
    return path


def ensure_loaded() -> None:
    if _LOADED_FROM is None:
        load_cache()


# ----------------------------------------------------------- heuristics ----
def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


#: attention ops reuse the (bm, bn, bk) entry format with attention
#: semantics — bk = kv tokens per program (for ``attn.paged_decode`` and
#: ``attn.ragged`` that is pages_per_program * page_size, with page_size
#: riding in the key's group_size slot), bn = KV-head tile (0 = all heads,
#: kernels self-heal to a divisor), bm = q tile (prefill only; decode and
#: ragged rows carry one query token each).
ATTN_OPS = ("attn.paged_decode", "attn.prefill", "attn.ragged")

#: page-walking ops share the paged-decode heuristics (and therefore, on
#: untuned hosts, the same pages-per-program — which keeps ragged decode
#: rows bit-identical to the bucketed decode path's blocked XLA twin)
_PAGED_ATTN_OPS = ("attn.paged_decode", "attn.ragged")


def attn_default_blocks(op: str, M: int, K: int, N: int,
                        group_size: int = 0) -> Dict[str, int]:
    """Heuristic tiles for the attention ops (shapes: M = batch rows, q
    length or packed token rows, K = kv context length, N = H * hd)."""
    if op in _PAGED_ATTN_OPS:
        ps = max(1, group_size)
        # small pages pay per-page gather overhead: cap the block at ~256
        # tokens so the XLA twin's page index stays narrow; larger pages
        # amortize and take 512-token blocks
        target = 256 if ps < 8 else 512
        bk = max(ps, min(_round_up(K, ps), _round_up(target, ps)))
        return {"bm": 1, "bn": 0, "bk": bk}
    bq = 128 if M >= 128 else max(8, _round_up(M, 8))
    bk = 128 if K >= 128 else max(8, _round_up(K, 8))
    return {"bm": bq, "bn": 0, "bk": bk}


def attn_candidate_blocks(op: str, M: int, K: int, N: int,
                          group_size: int = 0) -> List[Dict[str, int]]:
    """Search space for the attention ops: kv-tokens-per-program x KV-head
    tiling (and q tiling for prefill)."""
    out, seen = [], set()
    if op in _PAGED_ATTN_OPS:
        ps = max(1, group_size)
        bks = sorted({max(ps, min(_round_up(K, ps), ps * pp))
                      for pp in (1, 4, 8, 32, 128)})
        bms = [1]
    else:
        bks = sorted({min(_round_up(K, 8), b) for b in (64, 128, 256)})
        bms = sorted({min(_round_up(max(M, 8), 8), b) for b in (64, 128, 256)})
    for bm in bms:
        for bn in (0, 2, 4):                       # head tile: all, 2, 4
            for bk in bks:
                key = (bm, bn, bk)
                if key not in seen:
                    seen.add(key)
                    out.append({"bm": bm, "bn": bn, "bk": bk})
    return out


#: the table-lookup GEMM has no MXU dot: per k-step cost is a fori_loop of
#: bk/2 two-level takes, so its sweet spot is smaller bk (shorter in-kernel
#: loop, more grid-level parallelism) and lane-wide bn (each take is a
#: full-width [bm, bn] vector op).  It gets its own candidate set.
LUT4_OP = "gemm.lut4"


def lut4_default_blocks(M: int, K: int, N: int) -> Dict[str, int]:
    bm = 128 if M >= 128 else max(8, _round_up(M, 8))
    bn = min(256, _round_up(N, 128)) if N >= 256 else 128
    bk = min(256, _round_up(K, 2))
    return {"bm": bm, "bn": bn, "bk": max(2, bk)}


def lut4_candidate_blocks(M: int, K: int, N: int) -> List[Dict[str, int]]:
    bms = sorted({b for b in (8, 32, 128) if b <= _round_up(max(M, 8), 8)}
                 | {lut4_default_blocks(M, K, N)["bm"]})
    bns = [b for b in (128, 256) if b <= _round_up(N, 128)] or [128]
    bks = sorted({max(2, _round_up(min(b, K), 2)) for b in (64, 128, 256, 512)})
    out, seen = [], set()
    for bm in bms:
        for bn in bns:
            for bk in bks:
                key = (bm, bn, bk)
                if key not in seen:
                    seen.add(key)
                    out.append({"bm": bm, "bn": bn, "bk": bk})
    return out


def default_blocks(M: int, K: int, N: int, group_size: int = 0) -> Dict[str, int]:
    """Shape-clipped MXU-aligned defaults.

    Constraints the kernels require: bk even (planar halves), and for
    grouped w4a16 scales bk a multiple of 2*group_size (each planar half of
    a k-step covers whole scale groups).  bm tracks small M (decode is
    M=1..batch; a 128-row tile would be >90% padding).
    """
    bm = 128 if M >= 128 else max(8, _round_up(M, 8))
    bn = 128
    step = 2 * group_size if group_size else 2
    bk = min(512, _round_up(K, step))
    bk = max(step, _round_up(bk, step))
    return {"bm": bm, "bn": bn, "bk": bk}


def candidate_blocks(M: int, K: int, N: int, group_size: int = 0
                     ) -> List[Dict[str, int]]:
    """Small MXU-aligned search space, constraint-filtered and deduped."""
    step = 2 * group_size if group_size else 2
    bms = sorted({b for b in (32, 64, 128, 256) if b <= _round_up(max(M, 8), 8)}
                 | {default_blocks(M, K, N, group_size)["bm"]})
    bns = [b for b in (128, 256) if b <= _round_up(N, 128)] or [128]
    bks = sorted({max(step, _round_up(min(b, K), step))
                  for b in (128, 256, 512, 1024)})
    out, seen = [], set()
    for bm in bms:
        for bn in bns:
            for bk in bks:
                key = (bm, bn, bk)
                if key not in seen:
                    seen.add(key)
                    out.append({"bm": bm, "bn": bn, "bk": bk})
    return out


# --------------------------------------------------------------- lookup ----
def get_blocks(op: str, M: int, K: int, N: int, dtype: str,
               group_size: int = 0, tag: str = "") -> Dict[str, int]:
    """Tuned blocks for this GEMM if cached (site-tagged entry first, then
    the shape-generic one), else heuristic defaults.  Cheap + pure: safe to
    call during jit tracing."""
    ensure_loaded()
    for key in ((cache_key(op, M, K, N, dtype, group_size, tag=tag),)
                if tag else ()) + (cache_key(op, M, K, N, dtype, group_size),):
        hit = _CACHE.get(key)
        if hit is not None:
            return {"bm": int(hit["bm"]), "bn": int(hit["bn"]),
                    "bk": int(hit["bk"])}
    if op in ATTN_OPS:
        return attn_default_blocks(op, M, K, N, group_size)
    if op == LUT4_OP:
        return lut4_default_blocks(M, K, N)
    return default_blocks(M, K, N, group_size)


def should_tune() -> bool:
    """Opt-in gate for implicit tuning: TPU hosts or REPRO_AUTOTUNE=1."""
    env = os.environ.get(ENV_AUTOTUNE)
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() == "tpu"


# --------------------------------------------------------------- search ----
def _default_timer(fn: Callable[[], object], reps: int = 3,
                   warmup: int = 1) -> float:
    """Median wall-time per call in microseconds."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def tune(op: str, make_call: Callable[[Dict[str, int]], Callable[[], object]],
         M: int, K: int, N: int, dtype: str, *,
         group_size: int = 0, tag: str = "",
         candidates: Optional[Iterable[Dict[str, int]]] = None,
         timer: Callable[[Callable[[], object]], float] = _default_timer,
         path: Optional[str] = None, save: bool = True
         ) -> Tuple[Dict[str, int], float]:
    """Time `make_call(blocks)()` over the candidate set, persist the best.

    `make_call` binds the kernel arguments and returns a zero-arg callable
    (one jit signature per block shape).  A candidate that fails to compile
    or run is skipped, not fatal.  Returns (best_blocks, best_us).
    """
    ensure_loaded()
    if candidates is not None:
        cands = list(candidates)
    elif op in ATTN_OPS:
        cands = attn_candidate_blocks(op, M, K, N, group_size)
    elif op == LUT4_OP:
        cands = lut4_candidate_blocks(M, K, N)
    else:
        cands = candidate_blocks(M, K, N, group_size)
    best, best_us = None, float("inf")
    for blocks in cands:
        try:
            us = timer(make_call(blocks))
        except _TILE_REJECT_ERRORS:
            # unsupported tile on this backend: bad block/grid shape
            # (ValueError / AssertionError from the wrapper contracts),
            # no Mosaic lowering (NotImplementedError), or a compile/run
            # failure (XlaRuntimeError).  Anything else — TypeError,
            # KeyboardInterrupt, a typo in make_call — propagates.
            global_registry().counter(
                "autotune_tiles_rejected_total",
                "autotune candidates skipped on lowering/compile failure",
                op=op).inc()
            continue
        if us < best_us:
            best, best_us = blocks, us
    if best is None:
        # every candidate failed: fall back to defaults but do NOT persist —
        # float("inf") is not valid JSON and a dead entry would shadow a
        # future successful search
        if op in ATTN_OPS:
            fallback = attn_default_blocks(op, M, K, N, group_size)
        elif op == LUT4_OP:
            fallback = lut4_default_blocks(M, K, N)
        else:
            fallback = default_blocks(M, K, N, group_size)
        return fallback, float("inf")
    entry = {**best, "us": best_us}
    _CACHE[cache_key(op, M, K, N, dtype, group_size, tag=tag)] = entry
    if tag:                                # untagged key serves other sites
        _CACHE.setdefault(cache_key(op, M, K, N, dtype, group_size), entry)
    if save:
        save_cache(path)
    return best, best_us
