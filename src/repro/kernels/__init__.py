"""Pallas TPU kernels for the paper's compute hot-spot: low-bit multiplication.

lut_mul4      -- the paper's LUT mechanism re-homed to VMEM (onehot/take)
int4_matmul   -- W4A4 planar-nibble MXU matmul (+ fused activation-quantize
                 variant) with fused dequant epilogue
w4a16_matmul  -- weight-only int4 serving matmul, activation-dtype MXU
                 contraction with scales folded into the epilogue
paged_attention -- fused paged-KV decode attention (reads pool pages in
                 place via scalar-prefetched block tables, online softmax)
                 + tiled flash prefill, with bit-exact XLA twins
packing       -- shared nibble pack/unpack layer (interleaved serialization
                 vs planar K-major kernel layout) + prepacked-weight cache
autotune      -- per-shape (bm, bn, bk) tile search with an on-disk cache
ops           -- public wrappers: layout conversion, block lookup, dispatch
                 (Pallas on TPU, interpreter for tests, XLA twin elsewhere)
ref           -- pure-jnp oracles
"""
from . import autotune, ops, packing, ref  # noqa: F401
