"""Pallas TPU kernels for the paper's compute hot-spot: low-bit multiplication.

lut_mul4      -- the paper's LUT mechanism re-homed to VMEM (onehot/take)
int4_matmul   -- W4A4 packed-nibble MXU matmul with fused dequant epilogue
w4a16_matmul  -- weight-only int4 serving matmul with per-group scales
ops           -- jit'd wrappers (+ pure-XLA equivalents for dry-runs)
ref           -- pure-jnp oracles
"""
from . import ops, ref  # noqa: F401
