"""Pallas TPU kernel: elementwise int4xint4 products via a VMEM product LUT.

This is the *direct* TPU translation of the paper's mechanism (a precomputed
truth table evaluated per operand pair).  The 256-entry int8 table lives in
VMEM next to the operand tiles.  Two lookup strategies:

  * ``onehot``  -- indices one-hot-encoded and contracted against the table
    with the MXU (`jnp.dot`).  This is the systolic-array-native realisation
    of "table lookup" and lowers on TPU unconditionally.
  * ``take``    -- lane-dim `take_along_axis` (VPU path): each output row
    reads its products out of a row-broadcast copy of the table via
    `packing.table_take`, the same vectorized lookup the table-lookup GEMM
    (`lut4_matmul.py`) runs per contraction row.  This replaced a serialized
    per-element flat `jnp.take` gather that was ~170x slower.

Both are validated against `ref.mul4_ref`.  The roofline story (see
EXPERIMENTS.md): a LUT lookup costs 256 MACs (onehot) or a vector gather
(take) per element versus 1 MAC for the native int8 multiply -- on TPU the
paper's insight pays off when the lookup is *amortized across a GEMM tile*
(see lut4_matmul.py) or traded for packing + MXU scheduling (int4_matmul.py);
we keep the elementwise forms to make that comparison concrete.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dispatch import default_interpret
from .packing import flatten_to_tiles, table_take
from .ref import make_product_lut

# VPU-aligned tile: 8 sublanes x 128 lanes.
DEFAULT_BLOCK = (256, 128)


def _kernel_onehot(a_ref, b_ref, lut_ref, o_ref):
    a = a_ref[...].astype(jnp.int32)
    b = b_ref[...].astype(jnp.int32)
    idx = ((a & 0xF) << 4) | (b & 0xF)                       # [bm, bn] in [0,256)
    oh = jax.nn.one_hot(idx, 256, dtype=jnp.float32)         # [bm, bn, 256]
    lut = lut_ref[...].astype(jnp.float32)                   # [256]
    prod = jax.lax.dot_general(
        oh.reshape(-1, 256), lut[:, None],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] = prod.reshape(idx.shape).astype(jnp.int8)


def _kernel_take(a_ref, b_ref, lut_ref, o_ref):
    a = a_ref[...].astype(jnp.int32)
    b = b_ref[...].astype(jnp.int32)
    idx = ((a & 0xF) << 4) | (b & 0xF)
    # The composite nibble-pair index collapses the row select (degenerate
    # single-row table), leaving the pure lane-dim take: every element of a
    # row gathers from the same 256-lane table copy in one vector op.
    rows = jnp.zeros((idx.shape[0],), jnp.int32)
    o_ref[...] = table_take(lut_ref[...].reshape(1, 256), rows, idx)


@functools.partial(jax.jit, static_argnames=("strategy", "block", "interpret"))
def lut_mul4(
    a_q: jnp.ndarray,
    b_q: jnp.ndarray,
    strategy: str = "onehot",
    block: tuple = DEFAULT_BLOCK,
    interpret: bool = None,
) -> jnp.ndarray:
    """Elementwise signed-int4 product of int8-valued tensors -> int8.

    Inputs are flattened to 2D tiles; arbitrary leading shapes supported.
    `interpret=None` auto-selects: compile on TPU, interpret elsewhere
    (CPU/GPU have no Mosaic lowering for this kernel); pass an explicit
    bool to override either way.
    """
    interpret = default_interpret(interpret)
    assert a_q.shape == b_q.shape
    shape = a_q.shape
    bm, cols = block
    # shared flatten/pad helper: one jnp.pad, not an O(n) zeros+scatter copy
    a2, n = flatten_to_tiles(a_q, bm, cols)
    b2, _ = flatten_to_tiles(b_q, bm, cols)
    rows_padded = a2.shape[0]
    assert rows_padded % bm == 0 and a2.shape[1] == cols, (a2.shape, block)
    lut = jnp.asarray(make_product_lut())

    kernel = _kernel_onehot if strategy == "onehot" else _kernel_take
    out = pl.pallas_call(
        kernel,
        grid=(rows_padded // bm,),
        in_specs=[
            pl.BlockSpec((bm, cols), lambda i: (i, 0)),
            pl.BlockSpec((bm, cols), lambda i: (i, 0)),
            pl.BlockSpec((256,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows_padded, cols), jnp.int8),
        interpret=interpret,
    )(a2, b2, lut)
    return out.reshape(-1)[:n].reshape(shape)
