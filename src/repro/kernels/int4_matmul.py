"""Pallas TPU kernel: W4A4 matmul — the paper's "dense 4-bit multiplier array"
re-architected for the MXU.

Hardware adaptation (DESIGN.md §2): on 7-series the win is LUT packing; on TPU
the win is (a) int4 *storage* packing — two weights per byte, 4x fewer HBM
bytes than bf16 — and (b) feeding the int8 MXU path (2x bf16 peak) with int32
accumulation, which replaces the CARRY4 chains.  The kernel:

  grid (M/bm, N/bn, K/bk), K innermost:
    k == 0     : zero the accumulator tile
    every k    : unpack the uint8 nibble tile -> int8 [bk, bn]; MXU dot with
                 the int8 activation tile; accumulate (exact in f32 < 2^24)
    k == K-1   : fuse the dequant epilogue  out *= a_scale[m] * w_scale[n]

Block shapes default to MXU-aligned (128, 128, 512).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, w_ref, as_ref, ws_ref, o_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]                                           # [bm, bk] int8
    wp = w_ref[...]                                          # [bk, bn//2] uint8
    lo = ((wp & 0xF) ^ 8).astype(jnp.int8) - 8               # sign-extend
    hi = (((wp >> 4) & 0xF) ^ 8).astype(jnp.int8) - 8
    w = jnp.stack([lo, hi], axis=-1).reshape(wp.shape[0], wp.shape[1] * 2)
    acc = jax.lax.dot_general(
        a, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )
    o_ref[...] += acc.astype(jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        o_ref[...] = o_ref[...] * as_ref[...] * ws_ref[...]


def _pad_to(x: jnp.ndarray, mult, axis: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def int4_matmul(
    a_q: jnp.ndarray,          # [M, K] int8 holding int4 values
    a_scale: jnp.ndarray,      # [M, 1] f32
    w_packed: jnp.ndarray,     # [K, N//2] uint8 (packed along N)
    w_scale: jnp.ndarray,      # [1, N] f32
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool = None,
) -> jnp.ndarray:
    M, K = a_q.shape
    N = w_packed.shape[1] * 2
    assert w_packed.shape[0] == K

    a_q = _pad_to(_pad_to(a_q, bm, 0), bk, 1)
    a_scale = _pad_to(a_scale, bm, 0)
    w_packed = _pad_to(_pad_to(w_packed, bk, 0), bn // 2, 1)
    w_scale = _pad_to(w_scale, bn, 1)
    Mp, Kp = a_q.shape
    Np = w_packed.shape[1] * 2
    nk = Kp // bk

    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=(Mp // bm, Np // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn // 2), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        interpret=(jax.default_backend() != "tpu"
                   if interpret is None else interpret),
    )(a_q, w_packed, a_scale, w_scale)
    return out[:M, :N]
