"""Pallas TPU kernel: W4A4 matmul — the paper's "dense 4-bit multiplier array"
re-architected for the MXU.

Hardware adaptation (DESIGN.md §2): on 7-series the win is LUT packing; on TPU
the win is (a) int4 *storage* packing — two weights per byte, 4x fewer HBM
bytes than bf16 — and (b) feeding the int8 MXU path (2x bf16 peak) with int32
accumulation, which replaces the CARRY4 chains.

Weights use the planar K-major layout (`kernels/packing.py`): the low nibbles
of a [bk/2, bn] uint8 tile ARE contraction rows [k0, k0+bk/2) and the high
nibbles ARE rows [K/2+k0, ...), so the in-kernel unpack is a shift/mask with
no stack/reshape relayout, and the two planar halves are two int8 MXU dots
accumulating into the same tile (the activation is split at K/2 to match).

  grid (M/bm, N/bn, K/bk), K innermost:
    k == 0     : zero the accumulator tile
    every k    : shift/mask-unpack the planar tile; two int8 MXU dots
                 (activations optionally quantized in-tile, see below)
    k == K-1   : fused dequant epilogue  out *= a_scale[m] * w_scale[n]

Two entry points:
  int4_matmul       -- pre-quantized int4 activations (a_q, a_scale)
  int4_matmul_fused -- float activations: the per-row int4 quantize runs
                       *inside* the same pallas_call (per-tile prologue), so
                       the A4 path is quantize + matmul + dequant in one
                       kernel and the int8 activation never round-trips HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dispatch import default_interpret
from .packing import pad_to, unpack_nibbles

INT4_QMAX = 7.0


def _quantize_tile(x, scale):
    """Per-row symmetric int4 quantize: same round/clip ops as
    core.quant.quantize on the same f32 values.

    Caveat: when x/scale lands *exactly* on a .5 rounding tie (possible with
    bf16 inputs, whose coarse grid makes exact ratios common), the fused
    kernel may round one LSB away from the eager oracle — XLA's fast-math
    fusion can evaluate the division as multiply-by-reciprocal, perturbing
    the quotient by 1 ulp across the tie.  A tie is a knife-edge
    quantization boundary; either neighbor is a valid int4 encoding."""
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -8, 7)
    return q.astype(jnp.int8)


def _kernel(alo_ref, ahi_ref, w_ref, as_ref, ws_ref, o_ref, *,
            nk: int, fused_quant: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a_lo = alo_ref[...]                     # [bm, bk/2] int8 (or float)
    a_hi = ahi_ref[...]
    if fused_quant:
        s = as_ref[...]                     # [bm, 1] f32
        a_lo = _quantize_tile(a_lo, s)
        a_hi = _quantize_tile(a_hi, s)
    lo, hi = unpack_nibbles(w_ref[...])     # planar: [bk/2, bn] int8 each
    acc = jax.lax.dot_general(
        a_lo, lo, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    ) + jax.lax.dot_general(
        a_hi, hi, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )
    o_ref[...] += acc.astype(jnp.float32)   # exact: |acc| < 2^24

    @pl.when(k == nk - 1)
    def _epilogue():
        o_ref[...] = o_ref[...] * as_ref[...] * ws_ref[...]


def _call(a, a_scale, w_kmajor, w_scale, *, bm, bn, bk, interpret, fused):
    M, K = a.shape
    N = w_kmajor.shape[1]
    Keven = w_kmajor.shape[0] * 2
    assert Keven in (K, K + 1), (a.shape, w_kmajor.shape)
    a = pad_to(a, Keven, 1)                 # odd K: one zero column
    assert bk % 2 == 0, bk
    bkh = bk // 2

    K2 = Keven // 2
    a_lo = pad_to(pad_to(a[:, :K2], bm, 0), bkh, 1)
    a_hi = pad_to(pad_to(a[:, K2:], bm, 0), bkh, 1)
    # pad rows get scale 1: the fused path divides by it (0 would NaN) and
    # the epilogue multiplies garbage rows that are sliced off anyway
    a_scale = pad_to(a_scale, bm, 0, value=1)
    w_kmajor = pad_to(pad_to(w_kmajor, bkh, 0), bn, 1)
    w_scale = pad_to(w_scale, bn, 1)
    Mp = a_lo.shape[0]
    Np = w_kmajor.shape[1]
    nk = a_lo.shape[1] // bkh

    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk, fused_quant=fused),
        grid=(Mp // bm, Np // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bkh), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, bkh), lambda i, j, k: (i, k)),
            pl.BlockSpec((bkh, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        interpret=default_interpret(interpret),
    )(a_lo, a_hi, w_kmajor, a_scale, w_scale)
    return out[:M, :N]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def int4_matmul(
    a_q: jnp.ndarray,          # [M, K] int8 holding int4 values
    a_scale: jnp.ndarray,      # [M, 1] f32
    w_kmajor: jnp.ndarray,     # [ceil(K/2), N] uint8, planar K-major
    w_scale: jnp.ndarray,      # [1, N] f32
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool = None,
) -> jnp.ndarray:
    return _call(a_q, a_scale, w_kmajor, w_scale,
                 bm=bm, bn=bn, bk=bk, interpret=interpret, fused=False)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def int4_matmul_fused(
    x: jnp.ndarray,            # [M, K] float activations (bf16/f32)
    w_kmajor: jnp.ndarray,     # [ceil(K/2), N] uint8, planar K-major
    w_scale: jnp.ndarray,      # [1, N] f32
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool = None,
) -> jnp.ndarray:
    """Fused activation-quantize A4 path: per-row scales are a cheap [M, K]
    reduction outside; round/clip/int8-cast + both MXU dots + the dequant
    epilogue all run in one pallas_call."""
    x32 = x.astype(jnp.float32)
    a_scale = jnp.maximum(jnp.max(jnp.abs(x32), axis=1, keepdims=True),
                          1e-8) / INT4_QMAX
    return _call(x32, a_scale, w_kmajor, w_scale,
                 bm=bm, bn=bn, bk=bk, interpret=interpret, fused=True)
