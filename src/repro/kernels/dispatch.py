"""One home for the backend-dispatch decision the kernel modules share.

Every Pallas wrapper takes ``interpret: Optional[bool]`` and needs the same
default when called directly (tests, benchmarks) rather than through
``kernels.ops``: compile via Mosaic on TPU, interpret elsewhere, with the
``REPRO_PALLAS_INTERPRET`` env override tests use.  Before this module each
kernel file re-derived that inline from ``jax.default_backend()`` — six
copies of one policy, invisible to review when one drifted.  The
``pallas-kernel-hygiene`` analysis rule now pins backend decisions to this
module and ``ops.py``; everything else must route through here.
"""

from __future__ import annotations

import os
from typing import Optional

import jax

ENV_INTERPRET = "REPRO_PALLAS_INTERPRET"


def env_interpret() -> Optional[bool]:
    """The test/debug override: unset -> None, '0'/'false' -> compile,
    anything else -> interpret."""
    env = os.environ.get(ENV_INTERPRET)
    if env is None:
        return None
    return env not in ("0", "false", "False")


def default_interpret(interpret: Optional[bool] = None) -> bool:
    """Resolve a wrapper's ``interpret=None`` default: explicit argument
    wins, then the env override, then Mosaic-on-TPU / interpret-elsewhere.

    ``ops.py`` never passes None here — its three-way Mosaic/interpret/
    XLA-twin dispatch already decided — so this only governs direct kernel
    calls."""
    if interpret is not None:
        return interpret
    env = env_interpret()
    if env is not None:
        return env
    return jax.default_backend() != "tpu"
