"""Public entry points for the kernels: layout conversion, block-size
lookup, and backend dispatch in one place.

Dispatch (the `interpret` argument):

  True   -- Pallas kernel through the interpreter (kernel-body tests on CPU)
  False  -- Pallas kernel compiled through Mosaic (TPU)
  None   -- ``REPRO_PALLAS_INTERPRET`` env if set ("0" => compile,
            anything else => interpret); otherwise Pallas-Mosaic on TPU and
            the pure-XLA twin elsewhere.  The interpreter is a debugging
            tool, not an execution path: on CPU/GPU the XLA twin is the
            same math at full XLA speed.

Weight layout: callers pass the *serialized* interleaved N-packed format
(``core.quant.pack_int4``, [K, N//2]); the wrappers convert to the kernels'
planar K-major layout through ``packing.prepack_kmajor`` (cached per
concrete array).  Call sites that already hold K-major weights (qdense
quantizing a float master inline) use the ``*_kmajor`` entry points.

Block sizes: resolved per GEMM shape through ``kernels.autotune`` unless
explicitly overridden (bm=/bn=/bk= kwargs).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.observability.metrics import global_registry

from . import autotune, packing, paged_attention, ragged_attention, ref
from .dispatch import env_interpret
from .int4_matmul import int4_matmul as _int4_matmul
from .int4_matmul import int4_matmul_fused as _int4_matmul_fused
from .lut4_matmul import lut4_matmul as _lut4_matmul
from .lut_mul4 import lut_mul4 as _lut_mul4
from .w4a16_matmul import w4a16_matmul as _w4a16_matmul

_PALLAS, _INTERPRET, _XLA = "pallas", "interpret", "xla"


def _mode(interpret: Optional[bool]) -> str:
    if interpret is True:
        return _INTERPRET
    if interpret is False:
        return _PALLAS
    env = env_interpret()
    if env is not None:
        return _INTERPRET if env else _PALLAS
    return _PALLAS if jax.default_backend() == "tpu" else _XLA


def _count_dispatch(op: str, mode: str) -> None:
    """Record a backend-dispatch decision in the process-global registry
    (these wrappers are module-level, with no engine to hang off).  Fires
    at trace time, so the count is per *compiled program* that uses the op
    — a steady-state serving run shows one bump per (op, jit signature),
    not one per step; a climbing count during steady state is the same
    smell JitWatch flags."""
    global_registry().counter(
        "kernel_dispatch_total",
        "kernel backend-dispatch decisions (counted at trace time)",
        op=op, mode=mode).inc()


def use_pallas(interpret: Optional[bool] = None) -> bool:
    """True when the Pallas kernels (compiled or interpreted) would run."""
    return _mode(interpret) != _XLA


def _blocks(op: str, M: int, K: int, N: int, dtype, group_size: int,
            tag: str, overrides: dict) -> dict:
    b = autotune.get_blocks(op, M, K, N, jnp.dtype(dtype).name,
                            group_size=group_size, tag=tag)
    b.update({k: v for k, v in overrides.items() if v is not None})
    return b


def mul4(a_q, b_q, strategy: str = "onehot",
         interpret: Optional[bool] = None):
    """Elementwise exact int4 product."""
    m = _mode(interpret)
    _count_dispatch("mul4", m)
    if m == _XLA:
        return ref.mul4_ref(a_q, b_q)
    return _lut_mul4(a_q, b_q, strategy=strategy,
                     interpret=m == _INTERPRET)


def int4_matmul(a_q, a_scale, w_packed, w_scale,
                interpret: Optional[bool] = None, tag: str = "",
                bm=None, bn=None, bk=None):
    """W4A4 matmul with fused dequant epilogue.

    `w_packed`: serialized interleaved [K, N//2] (``core.quant.pack_int4``).
    """
    m = _mode(interpret)
    if m == _XLA:
        _count_dispatch("int4_matmul", m)
        return ref.int4_matmul_ref(a_q, a_scale, w_packed, w_scale)
    return int4_matmul_kmajor(
        a_q, a_scale, packing.prepack_kmajor(w_packed), w_scale,
        interpret=m == _INTERPRET, tag=tag, bm=bm, bn=bn, bk=bk)


def int4_matmul_kmajor(a_q, a_scale, w_kmajor, w_scale,
                       interpret: Optional[bool] = None, tag: str = "",
                       bm=None, bn=None, bk=None):
    """W4A4 matmul on planar K-major weights ([ceil(K/2), N] uint8)."""
    m = _mode(interpret)
    _count_dispatch("int4_matmul_kmajor", m)
    if m == _XLA:
        w_q = packing.unpack_kmajor(w_kmajor)[: a_q.shape[1]]
        acc = jnp.dot(a_q, w_q, preferred_element_type=jnp.int32)
        return acc.astype(jnp.float32) * a_scale * w_scale
    M, K = a_q.shape
    b = _blocks("int4_matmul", M, K, w_kmajor.shape[1], a_q.dtype, 0, tag,
                {"bm": bm, "bn": bn, "bk": bk})
    return _int4_matmul(a_q, a_scale, w_kmajor, w_scale,
                        interpret=m == _INTERPRET, **b)


def lut4_matmul(a_q, a_scale, w_packed, w_scale,
                interpret: Optional[bool] = None, tag: str = "",
                bm=None, bn=None, bk=None):
    """Table-lookup W4A4 matmul (`kernels/lut4_matmul.py`).

    `w_packed`: serialized interleaved [K, N//2] (``core.quant.pack_int4``).
    """
    m = _mode(interpret)
    if m == _XLA:
        _count_dispatch("lut4_matmul", m)
        # XLA twin: the exact product table is rank-1 (T[a, w] = a*w), so
        # the lookup-sum collapses to the int8 dot — bit-identical because
        # integer accumulation is exact (see ref.lut4_matmul_ref).
        return ref.int4_matmul_ref(a_q, a_scale, w_packed, w_scale)
    return lut4_matmul_kmajor(
        a_q, a_scale, packing.prepack_kmajor(w_packed), w_scale,
        interpret=m == _INTERPRET, tag=tag, bm=bm, bn=bn, bk=bk)


def lut4_matmul_kmajor(a_q, a_scale, w_kmajor, w_scale,
                       interpret: Optional[bool] = None, tag: str = "",
                       bm=None, bn=None, bk=None):
    """Table-lookup W4A4 matmul on planar K-major weights.

    Block sizes resolve through ``kernels.autotune`` op ``gemm.lut4``, which
    carries its own candidate set (cost scales with the per-tile lookup loop
    over bk/2 packed rows, so it favors smaller bk than the MXU kernels).
    """
    m = _mode(interpret)
    _count_dispatch("lut4_matmul_kmajor", m)
    if m == _XLA:
        w_q = packing.unpack_kmajor(w_kmajor)[: a_q.shape[1]]
        acc = jnp.dot(a_q, w_q, preferred_element_type=jnp.int32)
        return acc.astype(jnp.float32) * a_scale * w_scale
    M, K = a_q.shape
    b = _blocks("gemm.lut4", M, K, w_kmajor.shape[1], a_q.dtype, 0, tag,
                {"bm": bm, "bn": bn, "bk": bk})
    return _lut4_matmul(a_q, a_scale, w_kmajor, w_scale,
                        interpret=m == _INTERPRET, **b)


def int4_matmul_fused(x, w_packed, w_scale,
                      interpret: Optional[bool] = None, tag: str = "",
                      bm=None, bn=None, bk=None):
    """Fused activation-quantize W4A4: float x in, quantize + matmul +
    dequant in one pallas_call (A4 activations never round-trip HBM)."""
    m = _mode(interpret)
    if m == _XLA:
        _count_dispatch("int4_matmul_fused", m)
        return ref.int4_matmul_fused_ref(x, w_packed, w_scale)
    return int4_matmul_fused_kmajor(
        x, packing.prepack_kmajor(w_packed), w_scale,
        interpret=m == _INTERPRET, tag=tag, bm=bm, bn=bn, bk=bk)


def int4_matmul_fused_kmajor(x, w_kmajor, w_scale,
                             interpret: Optional[bool] = None, tag: str = "",
                             bm=None, bn=None, bk=None):
    m = _mode(interpret)
    _count_dispatch("int4_matmul_fused_kmajor", m)
    if m == _XLA:
        # kmajor-holding caller on a non-Pallas backend (e.g. qdense traced
        # on CPU): same math through the XLA twin
        a_q, a_scale = _quantize_rows(x)
        w_q = packing.unpack_kmajor(w_kmajor)[: x.shape[1]]
        acc = jnp.dot(a_q, w_q, preferred_element_type=jnp.int32)
        return acc.astype(jnp.float32) * a_scale * w_scale
    M, K = x.shape
    b = _blocks("int4_matmul_fused", M, K, w_kmajor.shape[1], x.dtype, 0,
                tag, {"bm": bm, "bn": bn, "bk": bk})
    return _int4_matmul_fused(x, w_kmajor, w_scale,
                              interpret=m == _INTERPRET, **b)


def w4a16_matmul(x, w_packed, w_scale, group_size: int,
                 interpret: Optional[bool] = None, tag: str = "",
                 bm=None, bn=None, bk=None):
    """Weight-only int4 matmul with per-group dequant.

    `w_packed`: serialized interleaved [K, N//2] (``core.quant.pack_int4``).
    """
    m = _mode(interpret)
    if m == _XLA:
        _count_dispatch("w4a16_matmul", m)
        return ref.w4a16_matmul_ref(x, w_packed, w_scale, group_size)
    # grouped scales: align K to 2*G at repack time so each planar half
    # covers whole groups (padding rows are zero int4 values)
    row_mult = 2 * group_size if w_scale.ndim == 3 else 2
    return w4a16_matmul_kmajor(
        x, packing.prepack_kmajor(w_packed, row_mult), w_scale, group_size,
        interpret=m == _INTERPRET, tag=tag, bm=bm, bn=bn, bk=bk)


def w4a16_matmul_kmajor(x, w_kmajor, w_scale, group_size: int,
                        interpret: Optional[bool] = None, tag: str = "",
                        bm=None, bn=None, bk=None):
    """W4A16 matmul on planar K-major weights ([ceil(K/2), N] uint8)."""
    m = _mode(interpret)
    _count_dispatch("w4a16_matmul_kmajor", m)
    if m == _XLA:
        w_q = packing.unpack_kmajor(w_kmajor)[: x.shape[1]]
        K, N = w_q.shape
        if w_scale.ndim == 2:
            w = w_q.astype(jnp.float32) * w_scale
        else:
            wg = w_q.reshape(K // group_size, group_size, N)
            w = (wg.astype(jnp.float32) * w_scale).reshape(K, N)
        return jnp.dot(x.astype(jnp.float32), w,
                       preferred_element_type=jnp.float32)
    M, K = x.shape
    g = 0 if w_scale.ndim == 2 else group_size
    b = _blocks("w4a16_matmul", M, K, w_kmajor.shape[1], x.dtype, g, tag,
                {"bm": bm, "bn": bn, "bk": bk})
    return _w4a16_matmul(x, w_kmajor, w_scale, group_size,
                         interpret=m == _INTERPRET, **b)


def paged_decode_attention(q, k_pool, v_pool, tbl, last_pos,
                           k_scale=None, v_scale=None, *, window: int = 0,
                           interpret: Optional[bool] = None, tag: str = ""):
    """Fused decode attention over the KV page pool (no gather, no dense
    [B, max_ctx] KV materialization).

    q [B, H, hd]; pools [P, ps, KV, hd(/2)] (+ per-token scales when the
    cache is int8/int4); tbl [B, pages_per_seq]; last_pos [B] (-1 = inactive
    row, masked to a zero output).  Tiles resolve through ``kernels.autotune``
    op ``attn.paged_decode`` — page size rides in the key's group_size slot,
    ``bk`` is kv tokens per program, ``bn`` the KV-head tile.
    """
    m = _mode(interpret)
    _count_dispatch("paged_decode_attention", m)
    B, H, hd = q.shape
    ps = k_pool.shape[1]
    max_ctx = tbl.shape[1] * ps
    b = autotune.get_blocks("attn.paged_decode", B, max_ctx, H * hd,
                            jnp.dtype(k_pool.dtype).name, group_size=ps,
                            tag=tag)
    pp = max(1, b["bk"] // ps)
    if m == _XLA:
        return paged_attention.paged_decode_attention_xla(
            q, k_pool, v_pool, tbl, last_pos, k_scale, v_scale,
            window=window, pp=pp)
    return paged_attention.paged_decode_attention(
        q, k_pool, v_pool, tbl, last_pos, k_scale, v_scale,
        window=window, pp=pp, bkv=b["bn"], interpret=m == _INTERPRET)


def ragged_paged_attention(q, k_pool, v_pool, tbl, token_slot, token_pos,
                           k_scale=None, v_scale=None, *, window: int = 0,
                           interpret: Optional[bool] = None, tag: str = ""):
    """Ragged token-major attention over the KV page pool: one launch for a
    flat ``[T, H, hd]`` pack of mixed prefill-chunk and decode rows.

    q [T, H, hd]; pools [P, ps, KV, hd(/2)] (+ per-token scales when the
    cache is int8/int4); tbl [max_batch, pages_per_seq]; token_slot /
    token_pos [T] (-1 = padding row, masked to a zero output).  Tiles
    resolve through ``kernels.autotune`` op ``attn.ragged`` with the same
    entry semantics as ``attn.paged_decode``."""
    m = _mode(interpret)
    _count_dispatch("ragged_paged_attention", m)
    T, H, hd = q.shape
    ps = k_pool.shape[1]
    max_ctx = tbl.shape[1] * ps
    b = autotune.get_blocks("attn.ragged", T, max_ctx, H * hd,
                            jnp.dtype(k_pool.dtype).name, group_size=ps,
                            tag=tag)
    pp = max(1, b["bk"] // ps)
    if m == _XLA:
        return ragged_attention.ragged_attention_xla(
            q, k_pool, v_pool, tbl, token_slot, token_pos, k_scale, v_scale,
            window=window, pp=pp)
    return ragged_attention.ragged_decode_attention(
        q, k_pool, v_pool, tbl, token_slot, token_pos, k_scale, v_scale,
        window=window, pp=pp, bkv=b["bn"], interpret=m == _INTERPRET)


def flash_prefill(q, k, v, q_positions, k_positions, *, window: int = 0,
                  interpret: Optional[bool] = None, tag: str = ""):
    """Tiled flash prefill with causal/validity masking: scores only exist
    as [bq, bk] tiles (online softmax), never as the [S, S] matrix.

    q [B, Sq, H, hd]; k/v [B, Skv, KV, hd]; positions [B, S] (-1 = pad).
    Tiles resolve through ``kernels.autotune`` op ``attn.prefill``.
    """
    m = _mode(interpret)
    _count_dispatch("flash_prefill", m)
    B, Sq, H, hd = q.shape
    b = autotune.get_blocks("attn.prefill", Sq, k.shape[1], H * hd,
                            jnp.dtype(q.dtype).name, tag=tag)
    if m == _XLA:
        return paged_attention.flash_prefill_xla(
            q, k, v, q_positions, k_positions, window=window, bk=b["bk"])
    return paged_attention.flash_prefill(
        q, k, v, q_positions, k_positions, window=window,
        bq=b["bm"], bk=b["bk"], bkv=b["bn"], interpret=m == _INTERPRET)


def _quantize_rows(x):
    from repro.core.quant import quant_scale, quantize

    x32 = x.astype(jnp.float32)
    a_scale = quant_scale(x32, axis=1, bits=4)
    return quantize(x32, a_scale, bits=4), a_scale


# --- pure-XLA equivalents (identical math; used in multi-device dry-runs) ---

def xla_int4_matmul(a_q, a_scale, w_packed, w_scale):
    return ref.int4_matmul_ref(a_q, a_scale, w_packed, w_scale)


def xla_w4a16_matmul(x, w_packed, w_scale, group_size: int):
    return ref.w4a16_matmul_ref(x, w_packed, w_scale, group_size)


def xla_mul4(a_q, b_q):
    return ref.mul4_ref(a_q, b_q)
