"""Public jit'd entry points for the kernels, with CPU-interpret fallback.

On a real TPU runtime, pass ``interpret=False`` (or set
``REPRO_PALLAS_INTERPRET=0``) and the kernels lower through Mosaic; in this
container everything is validated through the Pallas interpreter.  The `xla_*`
functions are the pure-XLA equivalents used inside full-model dry-runs (Pallas
TPU kernels cannot lower on the CPU backend).
"""

from __future__ import annotations

import os
from typing import Optional

import jax

from . import ref
from .int4_matmul import int4_matmul as _int4_matmul
from .lut_mul4 import lut_mul4 as _lut_mul4
from .w4a16_matmul import w4a16_matmul as _w4a16_matmul


def _default_interpret(flag: Optional[bool]) -> bool:
    if flag is not None:
        return flag
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def mul4(a_q, b_q, strategy: str = "onehot", interpret: Optional[bool] = None):
    """Elementwise exact int4 product (Pallas)."""
    return _lut_mul4(a_q, b_q, strategy=strategy,
                     interpret=_default_interpret(interpret))


def int4_matmul(a_q, a_scale, w_packed, w_scale,
                interpret: Optional[bool] = None, **blocks):
    """W4A4 matmul with fused dequant epilogue (Pallas)."""
    return _int4_matmul(a_q, a_scale, w_packed, w_scale,
                        interpret=_default_interpret(interpret), **blocks)


def w4a16_matmul(x, w_packed, w_scale, group_size: int,
                 interpret: Optional[bool] = None, **blocks):
    """Weight-only int4 matmul with per-group dequant (Pallas)."""
    return _w4a16_matmul(x, w_packed, w_scale, group_size,
                         interpret=_default_interpret(interpret), **blocks)


# --- pure-XLA equivalents (identical math; used in multi-device dry-runs) ---

def xla_int4_matmul(a_q, a_scale, w_packed, w_scale):
    return ref.int4_matmul_ref(a_q, a_scale, w_packed, w_scale)


def xla_w4a16_matmul(x, w_packed, w_scale, group_size: int):
    return ref.w4a16_matmul_ref(x, w_packed, w_scale, group_size)


def xla_mul4(a_q, b_q):
    return ref.mul4_ref(a_q, b_q)
