"""Ragged token-major paged attention: one launch for mixed prefill+decode.

The bucketed serving step runs prefill and decode as separate jits over
padded batch shapes, so a mixed step pays two launches plus the padding of
both buckets, and every new bucket is a recompile.  This kernel is the
serving-side version of the paper's dense-packing argument: pack every
live request's tokens — chunked-prefill slices and single decode tokens
alike — into one flat ``[total_tokens, ...]`` buffer (the MAX
``flash_attention_ragged`` idiom) and attend them all in one grid.

Each packed row carries two scalars:

  ``token_slot[t]``  which request (block-table row) the token belongs to
                     (-1 = padding row),
  ``token_pos[t]``   its absolute position in that request's sequence
                     (-1 = padding row).

The engine writes the step's K/V through the block tables *before*
attending (``kv_pages.ragged_paged_write``), so by the time this kernel
runs the pool holds every position ``<= token_pos[t]`` for row ``t`` and
the decode mask ``pos <= token_pos`` is exactly causal for prefill rows
and exactly last-token for decode rows — one rule covers both.

``ragged_decode_attention``
    One program per (token row, KV-head tile); grid (T, nh, nj).  The
    per-token slot/pos vectors and the whole block-table matrix ride in as
    scalar-prefetch operands, so the BlockSpec index_map resolves
    ``tbl[slot[t], logical_page]`` to a physical pool page per program —
    the same in-place page walk as ``paged_decode_attention``, just
    indexed per token instead of per batch row.

``ragged_attention_xla``
    The twin CPU/GPU hosts execute and the compare harness gates.  It
    gathers each token's table row (padding rows get the out-of-bounds
    sentinel page) and defers to ``paged_decode_attention_xla`` with
    batch == tokens — so ragged decode rows are *bit-identical* to the
    bucketed fused/gather decode paths by construction, and prefill rows
    get the identical exact-softmax-over-pages math the tail-prefill
    (prefill-over-cache) path runs.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .dispatch import default_interpret
from .paged_attention import (
    NEG_INF,
    _dequant_slab,
    _largest_divisor,
    _round_scores,
    paged_decode_attention_xla,
)


def _ragged_kernel(slot_ref, pos_ref, tbl_ref, q_ref, *refs, pp: int,
                   ps: int, nj: int, G: int, bkv: int, hd: int, window: int,
                   quant: bool, scale: float):
    # slot_ref/tbl_ref are consumed by the BlockSpec index_maps; the body
    # only needs the token's own position for masking.
    del slot_ref, tbl_ref
    k_refs = refs[:pp]
    v_refs = refs[pp:2 * pp]
    i = 2 * pp
    if quant:
        ks_refs = refs[i:i + pp]
        vs_refs = refs[i + pp:i + 2 * pp]
        i += 2 * pp
    o_ref, acc_ref, m_ref, l_ref = refs[i:i + 4]

    t, j = pl.program_id(0), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    tp = pos_ref[t]
    cd = q_ref.dtype
    qh = q_ref[0].reshape(bkv, G, hd)              # [bkv, G, hd]

    for u in range(pp):                            # static unroll: pages
        kb = k_refs[u][0]                          # [ps, bkv, hd(/2)]
        vb = v_refs[u][0]
        if quant:
            kb = _dequant_slab(kb, ks_refs[u][0], hd)
            vb = _dequant_slab(vb, vs_refs[u][0], hd)
        s = jax.lax.dot_general(
            qh, kb.transpose(1, 0, 2).astype(cd),
            (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        s = _round_scores(s, cd) * scale

        logical = j * pp + u
        pos = logical * ps + jax.lax.broadcasted_iota(
            jnp.int32, (1, 1, ps), 2)
        # pos <= token_pos is causal for prefill rows (the chunk's K/V is
        # already in the pool) and last-token for decode rows; padding rows
        # (token_pos == -1) mask everything and emit zeros.
        mask = (pos <= tp) & (tp >= 0)
        if window:
            mask &= (tp - pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            p, vb.transpose(1, 0, 2).astype(jnp.float32),
            (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)        # [bkv, G, hd]
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    @pl.when(j == nj - 1)
    def _emit():
        l = l_ref[...]
        out = acc_ref[...] / jnp.where(l > 0, l, 1.0)  # padding row -> 0
        o_ref[...] = out.reshape(1, bkv * G, hd).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "pp", "bkv", "interpret"))
def ragged_decode_attention(
    q: jnp.ndarray,            # [T, H, hd] packed token rows
    k_pool: jnp.ndarray,       # [P, ps, KV, hd]  (uint8: [..., hd//2])
    v_pool: jnp.ndarray,
    tbl: jnp.ndarray,          # [max_batch, pages_per_seq] int32
    token_slot: jnp.ndarray,   # [T] int32 table row per token (-1 = pad)
    token_pos: jnp.ndarray,    # [T] int32 absolute position (-1 = pad)
    k_scale: jnp.ndarray = None,   # [P, ps, KV, 1] f32 when quantized
    v_scale: jnp.ndarray = None,
    window: int = 0,
    pp: int = 4,               # pages per program (autotuned: attn.ragged)
    bkv: int = 0,              # KV-head tile, 0 = all heads
    interpret: bool = None,
) -> jnp.ndarray:
    T, H, hd = q.shape
    P, ps, KV = k_pool.shape[:3]
    maxB, pps = tbl.shape
    assert H % KV == 0, (H, KV)           # query heads tile evenly over KV heads
    G = H // KV
    quant = k_scale is not None

    bkv = _largest_divisor(KV, bkv if bkv > 0 else KV)
    assert KV % bkv == 0, (KV, bkv)       # _largest_divisor contract
    pp = max(1, min(pp, pps))
    nj = -(-pps // pp)
    nh = KV // bkv
    interpret = default_interpret(interpret)

    tbl = tbl.astype(jnp.int32)
    token_slot = token_slot.astype(jnp.int32)
    token_pos = token_pos.astype(jnp.int32)

    def page_spec(u):
        # two scalar hops per program: token row -> table row -> physical
        # page.  Padding rows (slot -1) clamp to row 0 and dead table slots
        # carry the out-of-bounds sentinel (== P); both clamp into bounds
        # and mask away in the kernel body.
        def index(t, h, j, slot_ref, pos_ref, tbl_ref):
            row = jnp.maximum(slot_ref[t], 0)
            logical = jnp.minimum(j * pp + u, pps - 1)
            return (jnp.minimum(tbl_ref[row, logical], P - 1), 0, h, 0)
        return index

    kv_block = k_pool.shape[-1]                    # hd, or hd//2 packed
    in_specs = [pl.BlockSpec((1, bkv * G, hd),
                             lambda t, h, j, s, p_, tb: (t, h, 0))]
    in_specs += [pl.BlockSpec((1, ps, bkv, kv_block), page_spec(u))
                 for u in range(pp)]
    in_specs += [pl.BlockSpec((1, ps, bkv, kv_block), page_spec(u))
                 for u in range(pp)]
    args = [q, *([k_pool] * pp), *([v_pool] * pp)]
    if quant:
        in_specs += [pl.BlockSpec((1, ps, bkv, 1), page_spec(u))
                     for u in range(pp)]
        in_specs += [pl.BlockSpec((1, ps, bkv, 1), page_spec(u))
                     for u in range(pp)]
        args += [*([k_scale] * pp), *([v_scale] * pp)]

    kernel = functools.partial(
        _ragged_kernel, pp=pp, ps=ps, nj=nj, G=G, bkv=bkv, hd=hd,
        window=window, quant=quant, scale=1.0 / math.sqrt(hd))
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(T, nh, nj),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, bkv * G, hd),
                                   lambda t, h, j, s, p_, tb: (t, h, 0)),
            scratch_shapes=[
                pltpu.VMEM((bkv, G, hd), jnp.float32),
                pltpu.VMEM((bkv, G, 1), jnp.float32),
                pltpu.VMEM((bkv, G, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((T, H, hd), q.dtype),
        interpret=interpret,
    )(token_slot, token_pos, tbl, *args)
    return out


@functools.partial(jax.jit, static_argnames=("window", "pp"))
def ragged_attention_xla(
    q, k_pool, v_pool, tbl, token_slot, token_pos,
    k_scale=None, v_scale=None, window: int = 0, pp: int = 4,
) -> jnp.ndarray:
    """Pure-XLA twin: gather each token's block-table row (padding rows
    become all-sentinel rows, so their clamped page fetches mask to zero)
    and run the exact-softmax blocked decode twin with batch == tokens.
    Per-token rows are independent in that twin, so decode tokens here are
    bit-identical to what the bucketed decode step produced for the same
    (pool, table, position) — regardless of how many rows share a step."""
    P = k_pool.shape[0]
    maxB = tbl.shape[0]
    slot = token_slot.astype(jnp.int32)
    tbl_pt = jnp.where(
        slot[:, None] >= 0,
        jnp.take(tbl.astype(jnp.int32), jnp.clip(slot, 0, maxB - 1), axis=0),
        P)                                          # [T, pages_per_seq]
    return paged_decode_attention_xla(
        q, k_pool, v_pool, tbl_pt, token_pos.astype(jnp.int32),
        k_scale, v_scale, window=window, pp=pp)
