"""Pure-jnp oracles for every kernel in `repro.kernels`.

These are the ground-truth semantics the Pallas kernels (and the FPGA netlist
simulation) are tested against with `assert_allclose` across shape/dtype
sweeps.  All integer paths are exact, so integer comparisons use equality.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.quant import unpack_int4
from .packing import nibble_product_tables, nmajor_to_kmajor, pad_to


def make_product_lut() -> np.ndarray:
    """256-entry signed-int4 product table: LUT[(a&0xF)<<4 | (b&0xF)] = a*b.

    This is the TPU re-homing of the paper's LUT-based multiplier: the full
    4x4-bit product space precomputed into a table small enough to live in
    VMEM (256 bytes), indexed instead of recomputed.  A view of the GEMM
    tables: t_lo[a, byte] for byte < 16 has a zero high nibble, so its first
    16 columns are exactly sext4(a) * sext4(b).
    """
    t_lo, _ = nibble_product_tables()
    return np.ascontiguousarray(t_lo[:, :16]).reshape(256)


def mul4_ref(a_q: jnp.ndarray, b_q: jnp.ndarray) -> jnp.ndarray:
    """Elementwise exact int4*int4 -> int8 product (values in [-56, 64])."""
    return (a_q.astype(jnp.int32) * b_q.astype(jnp.int32)).astype(jnp.int8)


def int4_matmul_ref(
    a_q: jnp.ndarray,          # [M, K] int8 holding int4 values
    a_scale: jnp.ndarray,      # [M, 1] f32
    w_packed: jnp.ndarray,     # [K, N//2] uint8 (two int4 per byte, packed on N)
    w_scale: jnp.ndarray,      # [1, N] f32
) -> jnp.ndarray:
    """W4A4 matmul: integer dot + per-row/per-col scale epilogue -> f32."""
    w_q = unpack_int4(w_packed, axis=-1)                     # [K, N] int8
    acc = jnp.dot(
        a_q.astype(jnp.int8), w_q, preferred_element_type=jnp.int32
    )
    return acc.astype(jnp.float32) * a_scale * w_scale


def lut4_matmul_ref(
    a_q: jnp.ndarray,          # [M, K] int8 holding int4 values
    a_scale: jnp.ndarray,      # [M, 1] f32
    w_packed: jnp.ndarray,     # [K, N//2] uint8 (two int4 per byte, packed on N)
    w_scale: jnp.ndarray,      # [1, N] f32
) -> jnp.ndarray:
    """Table-formulation W4A4 oracle: every partial product is *read* from
    the 16x256 per-nibble tables (never multiplied), then summed in int32.

    Materializes the [M, K/2, N] partial-product cube, so test shapes only.
    Bitwise equal to `int4_matmul_ref` because the exact product table is
    rank-1 (T[a, w] = a*w) and integer sums are exact — that identity is
    what makes the XLA twin of the `lut4` backend an int8 dot.
    """
    t_lo, t_hi = (jnp.asarray(t) for t in nibble_product_tables())
    wb = nmajor_to_kmajor(w_packed).astype(jnp.int32)        # [Kh, N]
    kh = wb.shape[0]
    a = pad_to(a_q, 2 * kh, 1)
    u_lo = (a[:, :kh] & 0xF).astype(jnp.int32)               # [M, Kh]
    u_hi = (a[:, kh:] & 0xF).astype(jnp.int32)
    pp = (t_lo[u_lo[:, :, None], wb[None, :, :]].astype(jnp.int32)
          + t_hi[u_hi[:, :, None], wb[None, :, :]])          # [M, Kh, N]
    acc = jnp.sum(pp, axis=1, dtype=jnp.int32)
    return acc.astype(jnp.float32) * a_scale * w_scale


def int4_matmul_fused_ref(
    x: jnp.ndarray,            # [M, K] float activations
    w_packed: jnp.ndarray,     # [K, N//2] uint8 (two int4 per byte, packed on N)
    w_scale: jnp.ndarray,      # [1, N] f32
) -> jnp.ndarray:
    """Oracle for the fused activation-quantize A4 path: dynamic per-row
    int4 quantization (same round/clip as core.quant.quantize) + W4A4."""
    from repro.core.quant import quant_scale, quantize

    x32 = x.astype(jnp.float32)
    a_scale = quant_scale(x32, axis=1, bits=4)
    a_q = quantize(x32, a_scale, bits=4)
    return int4_matmul_ref(a_q, a_scale, w_packed, w_scale)


def w4a16_matmul_ref(
    x: jnp.ndarray,            # [M, K] bf16/f32
    w_packed: jnp.ndarray,     # [K, N//2] uint8
    w_scale: jnp.ndarray,      # [K//G, 1, N] f32 (or [1, N] per-channel)
    group_size: int,
) -> jnp.ndarray:
    """Weight-only int4 serving matmul: dequantize then bf16 GEMM -> f32."""
    w_q = unpack_int4(w_packed, axis=-1)                     # [K, N] int8
    K, N = w_q.shape
    if w_scale.ndim == 2:
        w = w_q.astype(jnp.float32) * w_scale
    else:
        wg = w_q.reshape(K // group_size, group_size, N).astype(jnp.float32)
        w = (wg * w_scale).reshape(K, N)
    return jnp.dot(x.astype(jnp.float32), w, preferred_element_type=jnp.float32)
