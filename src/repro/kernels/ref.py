"""Pure-jnp oracles for every kernel in `repro.kernels`.

These are the ground-truth semantics the Pallas kernels (and the FPGA netlist
simulation) are tested against with `assert_allclose` across shape/dtype
sweeps.  All integer paths are exact, so integer comparisons use equality.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.quant import unpack_int4


def make_product_lut() -> np.ndarray:
    """256-entry signed-int4 product table: LUT[(a&0xF)<<4 | (b&0xF)] = a*b.

    This is the TPU re-homing of the paper's LUT-based multiplier: the full
    4x4-bit product space precomputed into a table small enough to live in
    VMEM (256 bytes), indexed instead of recomputed.
    """
    t = np.zeros(256, dtype=np.int8)
    for a in range(16):
        sa = a - 16 if a >= 8 else a
        for b in range(16):
            sb = b - 16 if b >= 8 else b
            t[(a << 4) | b] = sa * sb
    return t


def mul4_ref(a_q: jnp.ndarray, b_q: jnp.ndarray) -> jnp.ndarray:
    """Elementwise exact int4*int4 -> int8 product (values in [-56, 64])."""
    return (a_q.astype(jnp.int32) * b_q.astype(jnp.int32)).astype(jnp.int8)


def int4_matmul_ref(
    a_q: jnp.ndarray,          # [M, K] int8 holding int4 values
    a_scale: jnp.ndarray,      # [M, 1] f32
    w_packed: jnp.ndarray,     # [K, N//2] uint8 (two int4 per byte, packed on N)
    w_scale: jnp.ndarray,      # [1, N] f32
) -> jnp.ndarray:
    """W4A4 matmul: integer dot + per-row/per-col scale epilogue -> f32."""
    w_q = unpack_int4(w_packed, axis=-1)                     # [K, N] int8
    acc = jnp.dot(
        a_q.astype(jnp.int8), w_q, preferred_element_type=jnp.int32
    )
    return acc.astype(jnp.float32) * a_scale * w_scale


def int4_matmul_fused_ref(
    x: jnp.ndarray,            # [M, K] float activations
    w_packed: jnp.ndarray,     # [K, N//2] uint8 (two int4 per byte, packed on N)
    w_scale: jnp.ndarray,      # [1, N] f32
) -> jnp.ndarray:
    """Oracle for the fused activation-quantize A4 path: dynamic per-row
    int4 quantization (same round/clip as core.quant.quantize) + W4A4."""
    from repro.core.quant import quant_scale, quantize

    x32 = x.astype(jnp.float32)
    a_scale = quant_scale(x32, axis=1, bits=4)
    a_q = quantize(x32, a_scale, bits=4)
    return int4_matmul_ref(a_q, a_scale, w_packed, w_scale)


def w4a16_matmul_ref(
    x: jnp.ndarray,            # [M, K] bf16/f32
    w_packed: jnp.ndarray,     # [K, N//2] uint8
    w_scale: jnp.ndarray,      # [K//G, 1, N] f32 (or [1, N] per-channel)
    group_size: int,
) -> jnp.ndarray:
    """Weight-only int4 serving matmul: dequantize then bf16 GEMM -> f32."""
    w_q = unpack_int4(w_packed, axis=-1)                     # [K, N] int8
    K, N = w_q.shape
    if w_scale.ndim == 2:
        w = w_q.astype(jnp.float32) * w_scale
    else:
        wg = w_q.reshape(K // group_size, group_size, N).astype(jnp.float32)
        w = (wg * w_scale).reshape(K, N)
    return jnp.dot(x.astype(jnp.float32), w, preferred_element_type=jnp.float32)
