"""Sharding rules: logical activation names + parameter-path rules ->
PartitionSpecs on the production mesh (DP x TP [x pod], GQA-aware).

A context-managed `MeshContext` makes the rules visible inside model code via
`shard(x, "act_btd")`-style constraints; with no context active the helpers
are no-ops so the same model code runs on a single CPU device.
"""

from __future__ import annotations

import contextlib
import re
import threading
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

# jax >= 0.5 exposes `jax.shard_map(..., check_vma=)`; 0.4.x has the
# experimental module with the same semantics under `check_rep=`.
try:
    _shard_map_impl = jax.shard_map
    _SM_CHECK_KW = "check_vma"
except AttributeError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_impl
    _SM_CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """Version-portable shard_map with replication checking off by default."""
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **{_SM_CHECK_KW: check})


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def dp_axes() -> Tuple[str, ...]:
    m = current_mesh()
    if m is None:
        return ()
    return ("pod", "data") if "pod" in m.axis_names else ("data",)


TP = "model"


@contextlib.contextmanager
def mesh_context(mesh: Optional[Mesh]):
    prev = getattr(_state, "mesh", None)
    _state.mesh = mesh
    try:
        if mesh is None:
            yield
        else:
            with mesh:
                yield
    finally:
        _state.mesh = prev


#: logical activation specs (model axis sizes are checked at constraint time)
def _act_spec(name: str) -> P:
    dp = dp_axes()
    dpa = dp if len(dp) > 1 else (dp[0] if dp else None)
    return {
        "act_btd": P(dpa, None, None),        # [B, S, D] replicated over TP
        "act_btf": P(dpa, None, TP),          # [B, S, F] FFN hidden
        "act_bthd": P(dpa, None, TP),         # [B, S, H*hd] combined heads
        "act_btv": P(dpa, None, TP),          # [B, S, V] logits
        "act_td": P(dpa, None),               # [T, D] flattened tokens
        "act_tv": P(dpa, TP),                 # [T, V] flattened logits
        "tokens": P(dpa, None),               # [B, S]
        "moe_expert": P(TP, None, None),      # [E, C, D] expert buffers
    }[name]


def tp_size() -> int:
    m = current_mesh()
    return m.shape[TP] if m is not None else 1


def shard_spec(x: jnp.ndarray, spec: P) -> jnp.ndarray:
    """Constraint with an explicit spec (no divisibility guard)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def shard(x: jnp.ndarray, name: str) -> jnp.ndarray:
    """Apply a logical sharding constraint if a mesh context is active and
    every named axis divides the corresponding array dimension."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = _act_spec(name)
    # divisibility guard: drop axes that do not divide
    fixed = []
    for dim, axes in zip(x.shape, tuple(spec) + (None,) * (x.ndim - len(spec))):
        if axes is None:
            fixed.append(None)
            continue
        ax_tuple = axes if isinstance(axes, tuple) else (axes,)
        size = 1
        for a in ax_tuple:
            size *= mesh.shape[a]
        fixed.append(axes if dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*fixed))
    )


# ------------------------------------------------------------ param rules --
#: (path regex, spec builder).  Specs written for *unstacked* params; a layer-
#: stacked param (extra leading dim from scan-over-layers) gets None prepended.
def param_spec(path: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
    tp = mesh.shape[TP]

    def fits(dim_idx: int) -> bool:
        return shape[dim_idx] % tp == 0

    rules = [
        (r"embed/tok$", lambda: P(TP, None) if fits(0) else P(None, None)),
        (r"(lm_head|router)/w$", lambda: P(None, TP) if fits(1) else P()),
        (r"w(q|k|v|kv|qkv)(/w)?$", lambda: P(None, TP) if fits(1) else P(None, None)),
        (r"w(q|k|v|qkv)_bias$", lambda: P(TP,) if fits(0) else P(None)),
        (r"wo(/w)?$", lambda: P(TP, None) if fits(0) else P(None, None)),
        (r"ffn/(w_in|w_gate)$", lambda: P(None, TP) if fits(1) else P(None, None)),
        (r"ffn/w_out$", lambda: P(TP, None) if fits(0) else P(None, None)),
        (r"ffn/(b_in|b_gate)$", lambda: P(TP,) if fits(0) else P(None)),
        # Experts: E over TP (expert parallelism) + F over data (FSDP-style
        # weight sharding; gathered per-layer inside the MoE shard_map body,
        # whose backward is the matching reduce-scatter).
        (r"experts/(w_in|w_gate)$", lambda: P(TP, None, "data")
            if shape[2] % mesh.shape["data"] == 0 else P(TP, None, None)),
        (r"experts/w_out$", lambda: P(TP, "data", None)
            if shape[1] % mesh.shape["data"] == 0 else P(TP, None, None)),
        (r"(mamba|lru)/in_proj$", lambda: P(None, TP) if fits(1) else P(None, None)),
        (r"(mamba|lru)/out_proj$", lambda: P(TP, None) if fits(0) else P(None, None)),
        (r"lru/w_(a|x)$", lambda: P(None, TP) if fits(1) else P(None, None)),
    ]
    for pat, builder in rules:
        if re.search(pat, path):
            spec = builder()
            return spec
    return P()                                                  # replicate


def stacked_param_spec(path: str, shape, mesh: Mesh, stacked: bool) -> P:
    inner_shape = shape[1:] if stacked else shape
    spec = param_spec(path, inner_shape, mesh)
    if stacked:
        return P(*((None,) + tuple(spec)))
    return spec


def make_param_shardings(params, mesh: Mesh, stacked_prefixes=("layers",),
                         zero: bool = False):
    """PartitionSpec pytree for a param tree (paths joined with '/').

    `zero=True` (ZeRO-style, for optimizer state trees): additionally shard
    the first yet-unsharded dimension divisible by the data-axis size, so
    fp32 Adam moments spread over the full mesh instead of only TP.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    dp = mesh.shape["data"]
    specs = []
    for keypath, leaf in flat:
        path = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in keypath
        )
        rel = path
        for pre in ("mu/", "nu/"):          # optimizer trees mirror params
            if rel.startswith(pre):
                rel = rel[len(pre):]
        for suf in ("/packed", "/scale"):   # packed serving weights
            if rel.endswith(suf):
                rel = rel[: -len(suf)]
        stacked = any(rel.startswith(p) for p in stacked_prefixes)
        spec = stacked_param_spec(rel, leaf.shape, mesh, stacked)
        if zero and "data" not in jax.tree.leaves(tuple(spec)):
            ax = list(spec) + [None] * (leaf.ndim - len(spec))
            for d in range(leaf.ndim):
                if ax[d] is None and leaf.shape[d] % dp == 0 and \
                        leaf.shape[d] >= dp:
                    ax[d] = "data"
                    break
            spec = P(*ax)
        specs.append(spec)
    return jax.tree_util.tree_unflatten(treedef, specs)


def specs_to_shardings(specs, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )
