"""GPipe-style pipeline parallelism over a mesh "stage" axis (opt-in).

The assignment's production mesh is DP x TP (+pod), so PP is provided as a
library feature rather than wired into the dry-run: `pipeline_apply` runs a
per-stage step function over microbatches with `shard_map`, passing
activations between stages with `jax.lax.ppermute` (the TPU-native analogue
of point-to-point sends).  The schedule is the classic GPipe fill/drain:
with S stages and M microbatches, each device computes M body steps and
idles for (S-1) bubble slots, overlapping the ppermute transfer of
microbatch i+1 with compute of microbatch i (XLA latency-hiding handles the
overlap once both appear in the unrolled schedule).

Tested on a host-platform fake mesh in tests/test_distributed.py.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.sharding import shard_map


def pipeline_apply(
    stage_fn: Callable,        # (stage_params, x_microbatch) -> y_microbatch
    params_stacked,            # pytree, leaves [n_stages, ...]
    x: jnp.ndarray,            # [n_micro * micro_batch, ...]
    *,
    mesh: Mesh,
    n_micro: int,
    stage_axis: str = "stage",
) -> jnp.ndarray:
    """Run x through n_stages sequential stage_fns, pipelined over microbatches."""
    n_stages = mesh.shape[stage_axis]
    assert x.shape[0] % n_micro == 0
    mb = x.shape[0] // n_micro

    def per_device(params_local, x_all):
        # params_local: this stage's params (leaves [1, ...] -> squeeze)
        params_local = jax.tree.map(lambda a: a[0], params_local)
        stage = jax.lax.axis_index(stage_axis)
        n_ticks = n_micro + n_stages - 1
        xs = x_all.reshape(n_micro, mb, *x_all.shape[1:])
        buf = jnp.zeros((mb,) + x_all.shape[1:], x_all.dtype)
        outs = jnp.zeros_like(xs)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (if in range); others take buf
            take = jnp.clip(t, 0, n_micro - 1)
            inp = jnp.where(stage == 0, xs[take], buf)
            y = stage_fn(params_local, inp)
            # pass to the next stage (ring; last stage's send is ignored)
            nxt = jax.lax.ppermute(
                y, stage_axis,
                [(i, (i + 1) % n_stages) for i in range(n_stages)],
            )
            # last stage emits microbatch t - (n_stages - 1)
            emit_idx = t - (n_stages - 1)
            valid = (emit_idx >= 0) & (stage == n_stages - 1)
            outs = jax.lax.cond(
                valid,
                lambda o: o.at[jnp.clip(emit_idx, 0, n_micro - 1)].set(y),
                lambda o: o,
                outs,
            )
            return (nxt, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(n_ticks))
        # only the last stage holds real outputs; psum replicates them
        outs = jax.lax.psum(outs, stage_axis)
        return outs.reshape(x_all.shape)

    y = shard_map(
        per_device,
        mesh=mesh,
        in_specs=(P(stage_axis), P()),
        out_specs=P(),
        check=False,
    )(params_stacked, x)
    return y
