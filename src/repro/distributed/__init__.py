"""Distribution layer: sharding rules, fault tolerance, pipeline parallelism."""
