"""Fault-tolerance runtime: step watchdog (straggler/hang detection),
bounded retry, and the restart policy used by `launch/train.py`.

Design for 1000+-node clusters (what of it runs here is tested; the rest is
policy glue that the cluster scheduler invokes):

  * **Checkpoint/restart** -- `CheckpointManager` (atomic, elastic) + a
    deterministic data pipeline keyed by step => a preempted job resumes
    bit-exact from the last checkpoint on any node count.
  * **Heartbeat watchdog** -- every training step arms a timer; if a step
    exceeds `deadline_s` (hung collective, dead host, straggler), the
    watchdog fires a callback (here: log + raise in tests; on a real
    cluster: abort the coordinator so the scheduler requeues the job --
    with jax.distributed, `jax.distributed.shutdown` + nonzero exit).
  * **Straggler mitigation** -- data prefetch decouples host input from the
    device step; the watchdog bounds tail latency; slow-host detection uses
    per-step wall-time EWMA vs the cluster median (`StepTimer.is_straggler`).
  * **Retryable steps** -- transient failures (preempted TPU slice raising
    `jax.errors.JaxRuntimeError`) are retried up to `max_retries` from the
    last checkpoint before surfacing.
"""

from __future__ import annotations

import logging
import threading
import time
from typing import Callable, Optional

log = logging.getLogger("repro.ft")


class StepDeadlineExceeded(RuntimeError):
    """A watched step (training or serving) overran its watchdog deadline.
    Raised by callers that run the Watchdog in strict mode — e.g. the
    serving engine with ``ServingConfig.step_deadline_strict`` — after the
    step returns; the watchdog itself cannot interrupt a hung device call,
    it can only make the overrun loud."""


class Watchdog:
    """Arms a deadline around each step; fires `on_timeout` if exceeded.
    Re-armable: ``arm()`` clears a previous firing, so one instance can
    guard every step of a long-running loop (the serving engine arms it
    once per ``step()``)."""

    def __init__(self, deadline_s: float, on_timeout: Optional[Callable] = None):
        self.deadline_s = deadline_s
        self.on_timeout = on_timeout or (lambda: None)
        self._timer: Optional[threading.Timer] = None
        self.fired = threading.Event()

    def arm(self):
        self.disarm()
        self.fired.clear()
        self._timer = threading.Timer(self.deadline_s, self._fire)
        self._timer.daemon = True
        self._timer.start()

    def _fire(self):
        self.fired.set()
        log.error("watchdog: step exceeded %.1fs deadline", self.deadline_s)
        self.on_timeout()

    def disarm(self):
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None

    def __enter__(self):
        self.arm()
        return self

    def __exit__(self, *exc):
        self.disarm()
        return False


class StepTimer:
    """EWMA step timing; flags stragglers vs a reference (median) time."""

    def __init__(self, alpha: float = 0.1):
        self.alpha = alpha
        self.ewma: Optional[float] = None
        self._t0: Optional[float] = None

    def start(self):
        self._t0 = time.monotonic()

    def stop(self) -> float:
        dt = time.monotonic() - self._t0
        self.ewma = dt if self.ewma is None else \
            (1 - self.alpha) * self.ewma + self.alpha * dt
        return dt

    def is_straggler(self, cluster_median_s: float, factor: float = 1.5) -> bool:
        return self.ewma is not None and self.ewma > factor * cluster_median_s


def run_with_retries(step_fn: Callable, *, max_retries: int = 3,
                     on_failure: Optional[Callable[[int, Exception], None]] = None):
    """Run `step_fn()`, retrying transient runtime failures."""
    for attempt in range(max_retries + 1):
        try:
            return step_fn()
        except Exception as e:  # noqa: BLE001 -- deliberate catch-all boundary
            if attempt >= max_retries:
                raise
            log.warning("step failed (attempt %d): %s -- retrying", attempt, e)
            if on_failure is not None:
                on_failure(attempt, e)
