"""Pipelined variant of the proposed multiplier (paper §VI).

Register boundary: after the first LUT level (P0..P2, C0, S1, S3 and the
Prop/Gen pairs) and before the carry chains.  Stage 1 therefore contains all
fabric logic; stage 2 contains only the CARRY4s, so the pipelined design
reaches a far higher Fmax at a latency of 2 cycles and II=1 -- exactly the
trade the paper motivates for multiplier arrays feeding accumulators.
"""

from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

from .mult4_proposed import build_proposed_mult4
from .timing import ARTIX7_CALIBRATED, DelayModel, pipeline_stage_cpds

#: signals registered between stage 1 and stage 2
STAGE1_REGS = (
    "P0", "P1", "P2", "C0",
    "Prop0", "Gen0", "Prop1", "Gen1", "Prop2", "Gen2", "Prop3", "Gen3",
)


def pipelined_report(model: DelayModel = ARTIX7_CALIBRATED) -> Dict[str, float]:
    return pipeline_stage_cpds(build_proposed_mult4(), STAGE1_REGS, model)


def pipelined_mult4(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Functional model (timing-transparent): identical results, 2-cycle latency."""
    return build_proposed_mult4()(a, b)
