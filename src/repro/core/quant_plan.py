"""Site-addressable quantization plans: mixed precision as a first-class
config object.

The paper's multiplier makes *dense arrays* of 4-bit products cheap, but real
deployments never quantize uniformly — sensitive sites (lm_head, first/last
blocks, attention output) keep higher precision while the bulk runs W4 (cf.
Vakili et al., dynamic per-operation reconfiguration; Böttcher & Kumm, mixed
sub-multiplier precisions inside one product).  A ``QuantPlan`` maps
glob-style *site patterns* to per-site ``QuantConfig``s:

    QuantPlan(rules=(
        ("block[0].attn.*", QuantConfig(backend="float")),
        ("ffn.*",           QuantConfig(backend="w4a16")),
        ("lm_head",         QuantConfig(backend="float")),
        ("*",               QuantConfig(backend="int_sim")),
    ))

Site names are hierarchical and unified with the autotune tile-tuning tags —
one site string keys both the quant choice and the (bm, bn, bk) tile lookup:

    block[<i>].attn.qkv | block[<i>].attn.wo          (i = global layer idx)
    block[<i>].ffn.{w_in,w_gate,w_out}
    block[<i>].moe.experts | block[<i>].shared.* | block[<i>].dense_ffn.*
    block[<i>].mamba.{in_proj,out_proj}
    block[<i>].lru.{in_x,in_g,w_a,w_x,out}
    lm_head

Matching: ``*``/``?`` are wildcards, every other character (including
``[``/``]``) is literal.  A pattern matches the full site or any
``.``-aligned suffix, so ``attn.qkv`` matches ``block[3].attn.qkv``.
Precedence is by *specificity* — the matching pattern with the most literal
characters wins (``block[0].attn.qkv`` beats ``attn.*`` beats ``*``); among
equal specificity, the later rule wins.

Plans come from three spec forms (``get_plan``): a named preset
(``uniform_w4a4``, ``w4a16_sensitive_fp``, ``qat_mixed``, ...), a JSON file
path, or an inline ``pattern=backend[/g<group>][;...]`` string — the latter
two are what ``--quant-plan <name|path>`` accepts on every launcher.

The scan-stacked layer loop constraint: ``lax.scan`` traces one body for all
repeat units, so per-site resolution must happen *outside* the scan body.
``plan_repeat_uniform`` decides whether every repeat unit resolves
identically (scan stays on, compiled graph static); a plan that
distinguishes repeats forces the unrolled layer loop, and
``plan_pack_tree`` then splits the stacked weights into per-repeat subtrees
(``layers = {"r0": ..., "r1": ...}``) so each layer can carry a different
weight format.
"""

from __future__ import annotations

import dataclasses
import functools
import json
import os
import re
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .qlinear import QuantConfig

#: backends the live serving path packs ahead of time (legacy-compatible:
#: exactly the set build_params always packed).
SERVE_PACKED = frozenset({"w4a4_packed", "w4a16_packed"})

#: backends a *quantized checkpoint* stores packed (everything that serves
#: from int4 nibbles; fake_quant/netlist/float sites keep float masters).
CKPT_PACKED = SERVE_PACKED | frozenset(
    {"int_sim", "pallas_int4", "lut4", "w4a16"})


def join_site(prefix: str, leaf: str) -> str:
    """``"block[3]" + "attn.qkv" -> "block[3].attn.qkv"``; empty prefix ok."""
    return f"{prefix}.{leaf}" if prefix else leaf


# ------------------------------------------------------------- matching ----
@functools.lru_cache(maxsize=4096)
def _compiled(pattern: str) -> "re.Pattern[str]":
    out = []
    for ch in pattern:
        if ch == "*":
            out.append(".*")
        elif ch == "?":
            out.append(".")
        else:
            out.append(re.escape(ch))
    return re.compile("".join(out) + r"\Z")


def pattern_matches(pattern: str, site: str) -> bool:
    """Full-site or dot-aligned-suffix glob match with literal brackets."""
    rx = _compiled(pattern)
    if rx.match(site):
        return True
    idx = site.find(".")
    while idx != -1:
        if rx.match(site[idx + 1:]):
            return True
        idx = site.find(".", idx + 1)
    return False


def specificity(pattern: str) -> int:
    """Number of literal (non-wildcard) characters — the precedence key."""
    return len(pattern) - pattern.count("*") - pattern.count("?")


# ----------------------------------------------------------------- plan ----
@dataclasses.dataclass(frozen=True)
class QuantPlan:
    """Ordered (pattern, QuantConfig) rules; frozen and hashable so it can
    key trace-time caches."""

    rules: Tuple[Tuple[str, QuantConfig], ...]
    name: str = ""

    def resolve(self, site: str) -> QuantConfig:
        return _resolve(self, site)

    @property
    def backends(self) -> frozenset:
        return frozenset(qc.backend for _, qc in self.rules)


@functools.lru_cache(maxsize=65536)
def _resolve(plan: QuantPlan, site: str) -> QuantConfig:
    best: Optional[QuantConfig] = None
    best_key = (-1, -1)
    for i, (pattern, qc) in enumerate(plan.rules):
        if not pattern_matches(pattern, site):
            continue
        key = (specificity(pattern), i)
        if key > best_key:
            best, best_key = qc, key
    if best is None:
        # a silent default here would let a typo'd plan (e.g. "ffn=w4a16"
        # with no "*" rule) serve the whole model unquantized while reports
        # label it quantized — fail loudly instead
        raise ValueError(
            f"site {site!r} matches no rule of plan "
            f"{plan.name or plan.rules!r}; add a catch-all '*' rule")
    return best


# -------------------------------------------------------- (de)serialize ----
_QC_FIELDS = ("backend", "w_bits", "a_bits", "group_size", "quantize_embedding")


def plan_to_dict(plan: QuantPlan) -> Dict:
    return {
        "name": plan.name,
        "rules": [
            {"pattern": pattern,
             **{f: getattr(qc, f) for f in _QC_FIELDS}}
            for pattern, qc in plan.rules
        ],
    }


def plan_from_dict(d: Dict) -> QuantPlan:
    rules = tuple(
        (r["pattern"],
         QuantConfig(**{f: r[f] for f in _QC_FIELDS if f in r}))
        for r in d["rules"]
    )
    return QuantPlan(rules=rules, name=d.get("name", ""))


def _parse_inline(spec: str) -> QuantPlan:
    """``"block[0].*=float;ffn.*=w4a16/g128;*=int_sim"`` -> QuantPlan."""
    rules = []
    for part in spec.split(";"):
        part = part.strip()
        if not part:
            continue
        pattern, _, rhs = part.partition("=")
        if not rhs:
            raise ValueError(f"bad plan rule {part!r}: expected pattern=backend")
        backend, *opts = rhs.split("/")
        kw = {"backend": backend.strip()}
        for opt in opts:
            if opt.startswith("g"):
                kw["group_size"] = int(opt[1:])
            elif opt.startswith("w"):
                kw["w_bits"] = int(opt[1:])
            elif opt.startswith("a"):
                kw["a_bits"] = int(opt[1:])
            else:
                raise ValueError(f"unknown plan option {opt!r} in {part!r}")
        rules.append((pattern.strip(), QuantConfig(**kw)))
    return QuantPlan(rules=tuple(rules), name="inline")


_FLOAT = QuantConfig(backend="float")

#: named presets — the spec forms every ``--quant-plan`` flag accepts.
PRESETS: Dict[str, QuantPlan] = {
    # uniform W4A4 integer GEMMs; lm_head stays float (the classic recipe)
    "uniform_w4a4": QuantPlan(
        name="uniform_w4a4",
        rules=(("*", QuantConfig(backend="int_sim")),
               ("lm_head", _FLOAT)),
    ),
    # weight-only int4 everywhere except the sensitive sites, which stay fp
    "w4a16_sensitive_fp": QuantPlan(
        name="w4a16_sensitive_fp",
        rules=(("*", QuantConfig(backend="w4a16", a_bits=16, group_size=128)),
               ("block[0].*", _FLOAT),
               ("lm_head", _FLOAT)),
    ),
    # QAT with the first block and head trained in full precision
    "qat_mixed": QuantPlan(
        name="qat_mixed",
        rules=(("*", QuantConfig(backend="fake_quant")),
               ("block[0].*", _FLOAT),
               ("lm_head", _FLOAT)),
    ),
    # pre-packed W4A4 serving (legacy `--quant w4a4_packed` as a plan)
    "serve_w4a4": QuantPlan(
        name="serve_w4a4",
        rules=(("*", QuantConfig(backend="w4a4_packed")),
               ("lm_head", _FLOAT)),
    ),
    # the mixed deployment plan: w4a16 FFNs, float lm_head + block-0
    # attention, int-sim W4A4 everywhere else
    "mixed_sensitive": QuantPlan(
        name="mixed_sensitive",
        rules=(("*", QuantConfig(backend="int_sim")),
               ("ffn.*", QuantConfig(backend="w4a16", a_bits=16)),
               ("block[0].attn.*", _FLOAT),
               ("lm_head", _FLOAT)),
    ),
}

_PLAN_CACHE: Dict[str, QuantPlan] = {}


def get_plan(spec: str) -> QuantPlan:
    """Resolve a plan spec: preset name | JSON file path | inline rules.
    File plans are cached per (path, mtime), so editing the file in a
    long-lived process picks up the new rules."""
    if spec in PRESETS:
        return PRESETS[spec]
    key = spec
    is_file = spec.endswith(".json") or os.path.exists(spec)
    if is_file:
        try:
            key = f"{spec}@{os.stat(spec).st_mtime_ns}"
        except OSError:
            pass
    hit = _PLAN_CACHE.get(key)
    if hit is not None:
        return hit
    if is_file:
        with open(spec) as f:
            plan = plan_from_dict(json.load(f))
    elif "=" in spec:
        plan = _parse_inline(spec)
    else:
        raise ValueError(
            f"unknown quant plan {spec!r}: not a preset "
            f"({sorted(PRESETS)}), not a file, and not inline rules "
            "(pattern=backend[;...])")
    _PLAN_CACHE[key] = plan
    return plan


@functools.lru_cache(maxsize=256)
def uniform_plan(qc: QuantConfig) -> QuantPlan:
    """The single-QuantConfig world as a plan (legacy-compatible: lm_head
    stays float unless the config opts in via quantize_embedding)."""
    rules: Tuple[Tuple[str, QuantConfig], ...] = (("*", qc),)
    if qc.quantized and not qc.quantize_embedding:
        rules += (("lm_head", dataclasses.replace(qc, backend="float")),)
    return QuantPlan(rules=rules, name=f"uniform_{qc.backend}")


def active_plan(arch, rt) -> QuantPlan:
    """The plan in effect for (arch, runtime).

    Precedence: ``Runtime.quant_plan`` (name|path|inline) > the deprecated
    ``Runtime.quant_backend`` string (mapped to a uniform plan so it keeps
    working) > ``ArchConfig.quant_plan`` > uniform ``ArchConfig.quant``.
    """
    rt_plan = getattr(rt, "quant_plan", None)
    if rt_plan:
        return get_plan(rt_plan)
    if rt.quant_backend is not None:
        return uniform_plan(
            dataclasses.replace(arch.quant, backend=rt.quant_backend))
    arch_plan = getattr(arch, "quant_plan", None)
    if arch_plan:
        return get_plan(arch_plan)
    return uniform_plan(arch.quant)


# ------------------------------------------------- scan-uniformity check ----
def block_leaf_sites(block_type: str, cfg) -> Tuple[str, ...]:
    """The quantizable leaf sites inside one block of the given type
    (relative to the block's ``block[<i>]`` prefix)."""
    ffn = ("ffn.w_in", "ffn.w_gate", "ffn.w_out")
    if block_type == "A":
        sites = ["attn.qkv", "attn.wo"]
        if cfg.family == "moe":
            sites.append("moe.experts")
            if cfg.shared_expert:
                sites += ["shared.w_in", "shared.w_gate", "shared.w_out"]
            if cfg.moe_dense_ff:
                sites += ["dense_ffn.w_in", "dense_ffn.w_gate",
                          "dense_ffn.w_out"]
        elif cfg.d_ff:
            sites += list(ffn)
        return tuple(sites)
    if block_type == "M":
        return ("mamba.in_proj", "mamba.out_proj")
    if block_type == "R":
        sites = ["lru.in_x", "lru.in_g", "lru.w_a", "lru.w_x", "lru.out"]
        if cfg.d_ff:
            sites += list(ffn)
        return tuple(sites)
    raise ValueError(block_type)


@functools.lru_cache(maxsize=1024)
def plan_repeat_uniform(plan: QuantPlan, cfg) -> bool:
    """True iff every scan repeat unit resolves to the same per-site configs
    as repeat 0 — the condition for keeping ``lax.scan`` over layers (one
    traced body for all repeats).  Resolved at trace time, outside the scan
    body, so the compiled graph stays static either way."""
    P = len(cfg.pattern)
    for j, bt in enumerate(cfg.pattern):
        for leaf in block_leaf_sites(bt, cfg):
            base = plan.resolve(f"block[{j}].{leaf}")
            for r in range(1, cfg.n_repeats):
                if plan.resolve(f"block[{r * P + j}].{leaf}") != base:
                    return False
    return True


# -------------------------------------------------- plan-aware packing ----
def _leaf_site(comps: Tuple[str, ...]) -> str:
    """Block-relative param path -> site leaf (wq/wk/wv unify to attn.qkv;
    expert stacks address as one <container>.experts site)."""
    if comps and comps[0] == "attn" and comps[-1] in ("wq", "wk", "wv"):
        return "attn.qkv"
    if "experts" in comps:
        return f"{comps[0]}.experts"
    return ".".join(comps)


def plan_pack_tree(params, cfg, plan: QuantPlan, *,
                   min_size: int = 1 << 12,
                   backends: frozenset = SERVE_PACKED,
                   scale_dtype=jnp.float32,
                   site_log: Optional[Dict[str, str]] = None):
    """Pack model weights into the int4 serving format *per resolved site*.

    Sites resolving to a backend outside ``backends`` (float, fake_quant,
    netlist, ...) keep their float masters.  With a repeat-uniform plan the
    stacked ``layers`` tree packs in place (scan-compatible); otherwise it
    splits into per-repeat subtrees ``{"r0": ..., "r1": ...}`` so different
    layers can carry different weight formats — the forward pass detects the
    split and unrolls.  ``scale_dtype=bfloat16`` is the quantized-checkpoint
    storage format (4x smaller artifacts; see checkpoint.save_quantized).

    ``site_log`` (optional dict, mutated in place) records which backend each
    *actually packed* site resolved to — the checkpoint manifest stores it so
    a restore can verify per-site that the serving plan rebuilds the same
    backend the nibbles were packed for (a ``lut4`` site silently served as
    nibble-unpack w4a4 would be a wrong-kernel bug, not just a perf bug)."""
    from .qlinear import PACKABLE_NAMES, pack_weight_nd

    def pack_leaf(leaf, site: str, *, check_name: Optional[str] = None):
        qc = plan.resolve(site)
        # expert stacks pack only for the pre-packing backends: live serving
        # of on-the-fly backends (int_sim/w4a16) runs experts from float
        # masters (models/moe.py dequantizes packed dicts but never
        # quantizes masters), so packing them into a checkpoint would change
        # the served math vs the same plan on masters
        site_backends = backends
        if site.endswith(".experts"):
            site_backends = backends & SERVE_PACKED
        packable = (
            qc.backend in site_backends
            and (check_name is None or check_name in PACKABLE_NAMES)
            and getattr(leaf, "ndim", 0) >= 2
            and leaf.size >= min_size
            and leaf.shape[-1] % 2 == 0
            and leaf.dtype in (jnp.float32, jnp.bfloat16)
        )
        if not packable:
            return leaf
        if site_log is not None:
            site_log[site] = qc.backend
        # grouped scales only exist for the weight-only backends (W4A4's
        # int32 accumulation runs over full K, so its scales are per-channel
        # by construction), and expert stacks dequantize per-channel in the
        # batched einsum (models/moe.py)
        if qc.backend not in ("w4a16", "w4a16_packed") \
                or site.endswith(".experts"):
            qc = dataclasses.replace(qc, group_size=0)
        packed = pack_weight_nd(leaf.astype(jnp.float32), qc)
        packed["scale"] = packed["scale"].astype(scale_dtype)
        return packed

    def pack_block(bp, prefix: str):
        def rec(node, comps):
            if isinstance(node, dict):
                return {k: rec(v, comps + (k,)) for k, v in node.items()}
            return pack_leaf(node, join_site(prefix, _leaf_site(comps)),
                             check_name=comps[-1])
        return rec(bp, ())

    P, R = len(cfg.pattern), cfg.n_repeats
    out = dict(params)
    layers = params["layers"]
    if plan_repeat_uniform(plan, cfg):
        out["layers"] = {
            f"u{j}": pack_block(layers[f"u{j}"], f"block[{j}]")
            for j in range(P)
        }
    else:
        out["layers"] = {
            f"r{r}": {
                f"u{j}": pack_block(
                    jax.tree.map(lambda a, r=r: a[r], layers[f"u{j}"]),
                    f"block[{r * P + j}]")
                for j in range(P)
            }
            for r in range(R)
        }
    for t in range(len(cfg.tail)):
        out[f"tail{t}"] = pack_block(params[f"tail{t}"], f"block[{R * P + t}]")
    if "lm_head" in params:
        out["lm_head"] = {
            "w": pack_leaf(params["lm_head"]["w"], "lm_head")}
    return out


def layers_per_repeat(params) -> bool:
    """True when ``params["layers"]`` was split per-repeat by a
    non-repeat-uniform plan (forward must unroll)."""
    layers = params.get("layers")
    return isinstance(layers, dict) and "r0" in layers


def pack_for_serving(params, cfg, rt):
    """Serving-side weight preparation under the active plan: pack the
    sites whose backend pre-packs (legacy ``w4a4_packed``/``w4a16_packed``),
    then add planar K-major twins on Pallas backends.  No-op when the plan
    never pre-packs — int_sim/w4a16 sites quantize on the fly from masters
    unless they come from a quantized checkpoint (checkpoint.restore_quantized
    hands back already-packed trees)."""
    from repro.kernels import ops

    from .qlinear import prepack_tree

    plan = active_plan(cfg, rt)
    if not (plan.backends & SERVE_PACKED):
        return params
    params = plan_pack_tree(params, cfg, plan, backends=SERVE_PACKED)
    if ops.use_pallas():
        params = prepack_tree(params)
    return params
