"""Bit-exact simulation of Xilinx 7-series logic primitives (LUT6 / LUT6_2 / CARRY4).

This module is the *faithful-reproduction substrate* for Kida & Sato's 4-bit
multiplier: it models exactly the primitives the paper instantiates in Verilog
(Section II / Fig. 1-2) and evaluates whole netlists either

  * ``mode="direct"``  -- each LUT's Boolean function evaluated symbolically
    (fast, vectorized jnp bitwise ops), or
  * ``mode="init"``    -- each LUT evaluated by indexing its synthesized 64-bit
    INIT truth table, i.e. exactly what the FPGA hardware does.

Both modes are pure-jnp, jittable and vmap-able over arbitrarily shaped uint8
bit tensors, so a netlist doubles as a vectorized "array of multipliers" -- the
deployment scenario the paper targets (Section I).

INIT semantics (matches Vivado's LUT6/LUT6_2 primitives):
  * LUT6:    O6 = INIT[ I5<<5 | I4<<4 | I3<<3 | I2<<2 | I1<<1 | I0 ]
  * LUT6_2:  O6 as above (I5 is tied to 1 in dual-output use, selecting the
             upper 32-bit half); O5 = INIT[ I4<<4 | ... | I0 ] (lower half).
Unused inputs are tied to logic '1' (paper, Table I caption).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Mapping, Optional, Sequence

import jax.numpy as jnp
import numpy as np

Bit = "jnp.ndarray"  # uint8 tensor holding 0/1
BoolFn = Callable[[Mapping[str, object]], object]

CONST0 = "0"
CONST1 = "1"


def _as_bit(x) -> jnp.ndarray:
    return jnp.asarray(x, dtype=jnp.uint8)


@dataclasses.dataclass(frozen=True)
class Lut:
    """A LUT6 (single output) or LUT6_2 (dual output, shared inputs).

    ``inputs`` are signal names in I0..I5 order; missing/extra positions are
    tied to '1' exactly as the paper does.  ``fn_o6``/``fn_o5`` map a dict of
    named input bits (ints 0/1 during INIT synthesis, jnp tensors during
    evaluation) to the output bit.  For a LUT6_2 both are given and the pair
    must share <=5 real inputs (hardware constraint; checked).
    """

    name: str
    inputs: Sequence[str]           # length <= 6, signal names or "0"/"1"
    fn_o6: BoolFn
    out_o6: str
    fn_o5: Optional[BoolFn] = None
    out_o5: Optional[str] = None

    def __post_init__(self):
        real = [s for s in self.inputs if s not in (CONST0, CONST1)]
        if len(self.inputs) > 6:
            raise ValueError(f"{self.name}: >6 inputs")
        if self.is_dual and len(real) > 5:
            raise ValueError(
                f"{self.name}: LUT6_2 dual-output allows at most 5 shared real "
                f"inputs (I5 must be tied high); got {real}"
            )

    @property
    def is_dual(self) -> bool:
        return self.fn_o5 is not None

    @property
    def padded_inputs(self) -> List[str]:
        """Inputs padded to length 6 with tied-'1' (paper convention)."""
        pads = [CONST1] * (6 - len(self.inputs))
        return list(self.inputs) + pads

    # -- INIT synthesis ----------------------------------------------------
    def init_value(self) -> int:
        """Synthesize the 64-bit INIT word from the Boolean functions.

        For dual-output LUTs the upper 32 bits hold O6 (with I5=1) and the
        lower 32 bits hold O5, per the LUT6_2 primitive.
        """
        init = 0
        ins = self.padded_inputs
        for idx in range(64):
            bits = {}
            ok = True
            for pos, sig in enumerate(ins):
                b = (idx >> pos) & 1
                if sig == CONST0:
                    if b != 0:
                        ok = False
                        break
                elif sig == CONST1:
                    if b != 1:
                        ok = False
                        break
                else:
                    bits[sig] = b
            if self.is_dual:
                if idx < 32:
                    # lower half: O5 truth table over I0..I4
                    fn = self.fn_o5
                else:
                    fn = self.fn_o6
            else:
                fn = self.fn_o6
            if not ok:
                # unreachable row under tie constraints; re-evaluate anyway so
                # the table is fully specified (use raw bits, ties included)
                bits = {
                    sig: (idx >> pos) & 1
                    for pos, sig in enumerate(ins)
                    if sig not in (CONST0, CONST1)
                }
            if int(bool(fn(bits))):
                init |= 1 << idx
        return init

    # -- evaluation ---------------------------------------------------------
    def eval_direct(self, env: Dict[str, jnp.ndarray]) -> None:
        env[self.out_o6] = _as_bit(self.fn_o6(env)) & jnp.uint8(1)
        if self.is_dual:
            env[self.out_o5] = _as_bit(self.fn_o5(env)) & jnp.uint8(1)

    def eval_init(self, env: Dict[str, jnp.ndarray]) -> None:
        init = self.init_value()
        lo = np.uint32(init & 0xFFFFFFFF)
        hi = np.uint32(init >> 32)
        ins = self.padded_inputs
        idx = None
        for pos, sig in enumerate(ins):
            if sig == CONST0:
                b = jnp.uint32(0)
            elif sig == CONST1:
                b = jnp.uint32(1)
            else:
                b = env[sig].astype(jnp.uint32)
            term = b << pos
            idx = term if idx is None else idx | term
        # O6 = INIT[idx] over the full 64-bit table (split into two u32 words)
        sel_hi = (idx >> 5) & 1
        k = idx & 31
        o6 = jnp.where(
            sel_hi == 1,
            (jnp.uint32(hi) >> k) & 1,
            (jnp.uint32(lo) >> k) & 1,
        ).astype(jnp.uint8)
        env[self.out_o6] = o6
        if self.is_dual:
            k5 = idx & 31
            env[self.out_o5] = ((jnp.uint32(lo) >> k5) & 1).astype(jnp.uint8)


@dataclasses.dataclass(frozen=True)
class Carry4:
    """The 7-series CARRY4 block: 4 (MUXCY + XORCY) stages.

    Per stage i:  O[i] = S[i] ^ C[i];  C[i+1] = S[i] ? C[i] : DI[i].
    ``cin`` may be a fabric signal (enters via CYINIT) or the name of another
    CARRY4's CO[3] (dedicated CO->CIN link -- ``cin_dedicated=True``), which
    matters only to the timing model.
    """

    name: str
    s: Sequence[str]                 # 4 signal names ("0"/"1" allowed)
    di: Sequence[str]                # 4 signal names
    cin: str
    o_out: Sequence[Optional[str]]   # names for O[0..3] (None = unused)
    co_out: Sequence[Optional[str]]  # names for CO[0..3] (None = unused)
    cin_dedicated: bool = False

    def evaluate(self, env: Dict[str, jnp.ndarray]) -> None:
        def get(sig):
            if sig == CONST0:
                return jnp.uint8(0)
            if sig == CONST1:
                return jnp.uint8(1)
            return env[sig]

        c = get(self.cin)
        for i in range(4):
            s_i = get(self.s[i])
            di_i = get(self.di[i])
            o_i = s_i ^ c
            c = jnp.where(s_i == 1, c, di_i).astype(jnp.uint8)
            if self.o_out[i] is not None:
                env[self.o_out[i]] = o_i
            if self.co_out[i] is not None:
                env[self.co_out[i]] = c


@dataclasses.dataclass
class Netlist:
    """An ordered netlist of LUTs and CARRY4s with named inputs/outputs."""

    name: str
    inputs: Sequence[str]
    outputs: Sequence[str]
    cells: Sequence[object]          # Lut | Carry4, in dependency order

    def evaluate_bits(
        self, env: Dict[str, jnp.ndarray], mode: str = "direct"
    ) -> Dict[str, jnp.ndarray]:
        env = dict(env)
        for cell in self.cells:
            if isinstance(cell, Lut):
                if mode == "init":
                    cell.eval_init(env)
                else:
                    cell.eval_direct(env)
            elif isinstance(cell, Carry4):
                cell.evaluate(env)
            else:
                raise TypeError(type(cell))
        return env

    def __call__(self, a: jnp.ndarray, b: jnp.ndarray, mode: str = "direct") -> jnp.ndarray:
        """Multiply unsigned 4-bit tensors elementwise through the netlist.

        ``a``/``b`` are integer tensors with values in [0, 15]; returns the
        uint8 product tensor, computed bit-by-bit through the simulated gates.
        """
        a = jnp.asarray(a)
        b = jnp.asarray(b)
        env: Dict[str, jnp.ndarray] = {}
        for i in range(4):
            env[f"A{i}"] = ((a >> i) & 1).astype(jnp.uint8)
            env[f"B{i}"] = ((b >> i) & 1).astype(jnp.uint8)
        env = self.evaluate_bits(env, mode=mode)
        out = jnp.zeros(jnp.broadcast_shapes(a.shape, b.shape), dtype=jnp.uint8)
        for i, sig in enumerate(self.outputs):
            out = out | (env[sig].astype(jnp.uint8) << i)
        return out

    # -- resource accounting (paper Table II) -------------------------------
    def lut_count(self) -> int:
        return sum(1 for c in self.cells if isinstance(c, Lut))

    def carry4_count(self) -> int:
        return sum(1 for c in self.cells if isinstance(c, Carry4))

    def dual_lut_count(self) -> int:
        return sum(1 for c in self.cells if isinstance(c, Lut) and c.is_dual)

    def init_table(self) -> Dict[str, int]:
        return {c.name: c.init_value() for c in self.cells if isinstance(c, Lut)}
