"""The proposed 11-LUT / 2-CARRY4 exact 4-bit multiplier (paper Fig. 4 + Table I).

Signal naming follows the paper: A0..A3 multiplicand bits, B0..B3 multiplier
bits, P0..P7 product bits.  Intermediate signals (S1, S3, C0, Prop*/Gen*) match
Table I.  The Boolean functions of Table I column 2 are normative; INIT words
are synthesized from them (see DESIGN.md §8 for why we do not transcribe the
printed INIT strings verbatim).

Arithmetic structure (derivation from the paper's Fig. 3/4 discussion):

  col0: P0 = A0B0
  col1: P1 = A1B0 ^ A0B1, carry c1 = A1B0·A0B1
  col2: {A2B0, A1B1, A0B2, c1}:  P2 = xor4,  C0 = "at least two" (the c1 term
        appears alone because c1=1 forces A1B1=1 -- the paper's logical
        dominance), and the quadruple-ones case is absorbed by adding
        T = A2B0·A1B1·A0B2 at column 3 (T=1 forces c1=1, v=4, and the
        weight-16 deficit is exactly C0(8) + T(8)).
  col3: trio (A1B2, A2B1, T) pre-summed into S1 with carry C1 = A1B2·A2B1
        (dominance: T=1 forces A1B2=A2B1=1); then the CARRY4 adds
        (S1 ^ A3B0) half-adder pair, A0B3 and C0:
            P3 = Prop0 ^ C0,   Prop0 = (S1^A3B0)^A0B3, Gen0 = (S1^A3B0)·A0B3
        with g = S1·A3B0 deferred to column 4 (added inside Prop1).
  col4: S2 = A3B1^A2B2^A1B3, S3 = S2 ^ C1 (carry C3 = S2·C1 deferred),
            P4 = Prop1 ^ CO0,  Prop1 = S3 ^ g,  Gen1 = S3·g,  g = S1·A3·B0
  col5: C2 = maj3(A3B1,A2B2,A1B3), S4 = A3B2^A2B3^C2,
            P5 = Prop2 ^ CO1,  Prop2 = S4 ^ C3,  Gen2 = S4·C3
  col6: C4 = maj3(A3B2,A2B3,C2),
            P6 = Prop3 ^ CO2,  Prop3 = A3B3 ^ C4, Gen3 = A3B3·C4
  col7: P7 = CO3, exported through a second CARRY4 (chain B) whose XORCY with
        S='0' turns the dedicated-carry CO into a fabric output -- the paper's
        two-CARRY4 trick that avoids the slow CO3->fabric->LUT path.

Exhaustive 256-pair exactness is asserted in tests (paper §V).
"""

from __future__ import annotations

from .netlist import CONST0, CONST1, Carry4, Lut, Netlist


def _and(*xs):
    out = None
    for x in xs:
        out = x if out is None else out & x
    return out


def build_proposed_mult4() -> Netlist:
    e = lambda env, n: env[n]  # noqa: E731

    lut1 = Lut(
        name="LUT1",
        inputs=["A0", "B1", "B0", "A1", CONST1, CONST1],
        fn_o6=lambda v: (v["A1"] & v["B0"]) ^ (v["A0"] & v["B1"]),
        out_o6="P1",
        fn_o5=lambda v: v["A0"] & v["B0"],
        out_o5="P0",
    )
    lut2 = Lut(
        name="LUT2",
        inputs=["A2", "B0", "A0", "B1", "A1", "B2"],
        fn_o6=lambda v: (v["A2"] & v["B0"])
        ^ (v["A1"] & v["B1"])
        ^ (v["A0"] & v["B2"])
        ^ ((v["A0"] & v["B1"]) & (v["A1"] & v["B0"])),
        out_o6="P2",
    )
    lut3 = Lut(
        name="LUT3",
        inputs=["B2", "A2", "B0", "A0", "B1", "A1"],
        fn_o6=lambda v: ((v["A1"] & v["B1"]) & (v["A0"] & v["B2"]))
        | ((v["A2"] & v["B0"]) & (v["A1"] & v["B1"]))
        | ((v["A2"] & v["B0"]) & (v["A0"] & v["B2"]))
        | ((v["A0"] & v["B1"]) & (v["A1"] & v["B0"])),
        out_o6="C0",
    )
    lut4 = Lut(
        name="LUT4",
        inputs=["A1", "B2", "A2", "A0", "B1", "B0"],
        fn_o6=lambda v: (v["A1"] & v["B2"])
        ^ (v["A2"] & v["B1"])
        ^ _and(v["A1"] & v["B1"], v["A0"] & v["B2"], v["A2"] & v["B0"]),
        out_o6="S1",
    )
    lut5 = Lut(
        name="LUT5",
        inputs=["B3", "A0", "S1", "A3", "B0", CONST1],
        fn_o6=lambda v: (v["S1"] ^ (v["A3"] & v["B0"])) ^ (v["A0"] & v["B3"]),
        out_o6="Prop0",
        fn_o5=lambda v: (v["S1"] ^ (v["A3"] & v["B0"])) & (v["A0"] & v["B3"]),
        out_o5="Gen0",
    )

    def _s2(v):
        return (v["A3"] & v["B1"]) ^ (v["A2"] & v["B2"]) ^ (v["A1"] & v["B3"])

    def _c1(v):
        return (v["A1"] & v["B2"]) & (v["A2"] & v["B1"])

    lut6 = Lut(
        name="LUT6",
        inputs=["B3", "A1", "B1", "A3", "B2", "A2"],
        fn_o6=lambda v: _s2(v) ^ _c1(v),
        out_o6="S3",
    )
    lut7 = Lut(
        name="LUT7",
        inputs=["B0", "S1", "A3", "S3", CONST1, CONST1],
        fn_o6=lambda v: v["S3"] ^ _and(v["S1"], v["A3"], v["B0"]),
        out_o6="Prop1",
        fn_o5=lambda v: v["S3"] & _and(v["S1"], v["A3"], v["B0"]),
        out_o5="Gen1",
    )

    def _c2(v):
        x, y, z = v["A3"] & v["B1"], v["A2"] & v["B2"], v["A1"] & v["B3"]
        return (x & y) | (y & z) | (x & z)

    def _s4(v):
        return (v["A3"] & v["B2"]) ^ (v["A2"] & v["B3"]) ^ _c2(v)

    def _c3(v):
        return _s2(v) & _c1(v)

    lut8 = Lut(
        name="LUT8",
        inputs=["A2", "B1", "B3", "A1", "B2", "A3"],
        fn_o6=lambda v: _s4(v) ^ _c3(v),
        out_o6="Prop2",
    )
    lut9 = Lut(
        name="LUT9",
        inputs=["A2", "B1", "B3", "A1", "B2", "A3"],
        fn_o6=lambda v: _s4(v) & _c3(v),
        out_o6="Gen2",
    )

    def _c4(v):
        x, y, z = v["A3"] & v["B2"], v["A2"] & v["B3"], _c2(v)
        return (x & y) | (y & z) | (x & z)

    lut10 = Lut(
        name="LUT10",
        inputs=["B2", "B1", "A3", "A1", "A2", "B3"],
        fn_o6=lambda v: (v["A3"] & v["B3"]) ^ _c4(v),
        out_o6="Prop3",
    )
    lut11 = Lut(
        name="LUT11",
        inputs=["A2", "B1", "B2", "A1", "B3", "A3"],
        fn_o6=lambda v: (v["A3"] & v["B3"]) & _c4(v),
        out_o6="Gen3",
    )

    chain_a = Carry4(
        name="CarryChainA",
        s=["Prop0", "Prop1", "Prop2", "Prop3"],
        di=["Gen0", "Gen1", "Gen2", "Gen3"],
        cin="C0",
        o_out=["P3", "P4", "P5", "P6"],
        co_out=[None, None, None, "CO3A"],
    )
    # Chain B: converts CO3A (dedicated CO->CIN link) into fabric output P7
    # via XORCY with S=0.  This is the paper's reason for the second CARRY4.
    chain_b = Carry4(
        name="CarryChainB",
        s=[CONST0, CONST0, CONST0, CONST0],
        di=[CONST0, CONST0, CONST0, CONST0],
        cin="CO3A",
        o_out=["P7", None, None, None],
        co_out=[None, None, None, None],
        cin_dedicated=True,
    )

    return Netlist(
        name="proposed",
        inputs=[f"A{i}" for i in range(4)] + [f"B{i}" for i in range(4)],
        outputs=[f"P{i}" for i in range(8)],
        cells=[lut1, lut2, lut3, lut4, lut5, lut6, lut7, lut8, lut9, lut10, lut11,
               chain_a, chain_b],
    )
