"""Quantized-linear backend registry.

``qdense`` used to be a monolithic if/elif chain; each backend is now a
registered function so plan resolution (core.quant_plan) can pick a backend
*per call site* and new backends are additions, not edits:

    @register_backend("my_backend")
    def _my_backend(w, x2, cfg, tag):    # w [K, N] float master, x2 [M, K]
        return ...                       # y2 [M, N]

The shared wrapper in ``qdense`` owns the batch flattening, reshape
epilogue, bias add and output-dtype cast that every backend used to
duplicate — a backend only computes the 2-D GEMM.  ``tag`` is the site
string: it keys per-call-site (bm, bn, bk) tile tuning in
``kernels.autotune`` (the same string keys the quant choice in the plan).
"""

from __future__ import annotations

from typing import Callable, Dict

import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.packing import pack_kmajor

from .quant import (
    fake_quant,
    group_dequantize,
    group_quantize,
    quant_scale,
    quantize,
    to_unsigned_mag,
)

BACKENDS: Dict[str, Callable] = {}


def register_backend(name: str):
    """Register ``fn(w, x2, cfg, tag) -> y2`` under ``name``."""
    def deco(fn):
        BACKENDS[name] = fn
        return fn
    return deco


def get_backend(name: str) -> Callable:
    try:
        return BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown quant backend {name!r}; registered: "
            f"{sorted(BACKENDS)}") from None


@register_backend("float")
def _float_backend(w, x2, cfg, tag):
    """Plain GEMM in the activation dtype (reference / ablation baseline)."""
    return jnp.dot(x2, w.astype(x2.dtype))


@register_backend("fake_quant")
def _fake_quant_backend(w, x2, cfg, tag):
    """QAT: STE fake-quant on weights (per-out-channel) and activations
    (per-token dynamic); float GEMM.  Training mode."""
    wq = fake_quant(w, axis=0, bits=cfg.w_bits)
    xq = fake_quant(x2, axis=-1, bits=cfg.a_bits)       # stays x dtype
    return jnp.dot(xq, wq.astype(x2.dtype))


def _int4_backend(w, x2, cfg, tag):
    """W4A4 integer GEMM: int8 dot, int32 accum, dequant epilogue.

    ``int_sim`` keeps the pure-XLA path (identical math to
    kernels/int4_matmul.py, usable inside multi-device pjit graphs);
    ``pallas_int4`` runs quantize + int8-MXU matmul + dequant in one
    pallas_call on TPU (XLA twin math elsewhere — see kernels.ops)."""
    xf = x2.astype(jnp.float32)
    w_scale = quant_scale(w, axis=0, bits=cfg.w_bits)    # [1, N]
    w_q = quantize(w, w_scale, bits=cfg.w_bits)
    # the Pallas kernels are int4-specific; other bit widths keep the XLA
    # path so cfg.a_bits/w_bits are honored on every backend
    if cfg.backend == "pallas_int4" and ops.use_pallas() \
            and cfg.a_bits == 4 and cfg.w_bits == 4:
        # quantize + matmul + dequant in one pallas_call; the weight is
        # packed K-major directly from the quantized master
        return ops.int4_matmul_fused_kmajor(xf, pack_kmajor(w_q), w_scale,
                                            tag=tag)
    a_scale = quant_scale(xf, axis=1, bits=cfg.a_bits)   # per-row
    a_q = quantize(xf, a_scale, bits=cfg.a_bits)
    acc = jnp.dot(a_q, w_q, preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * a_scale * w_scale


register_backend("int_sim")(_int4_backend)
register_backend("pallas_int4")(_int4_backend)


@register_backend("lut4")
def _lut4_backend(w, x2, cfg, tag):
    """W4A4 through the paper's LUT multiplier, amortized across a GEMM tile
    (kernels/lut4_matmul.py): every partial product is *read* out of the
    16x256 per-nibble tables with a lane-dim take and accumulated in int32
    on the VPU — no MXU dot, weights stay nibble-packed in-kernel.

    The exact product table is rank-1 (T[a, w] = a*w), so the XLA twin is
    the same int8 dot as ``int_sim`` — bit-identical logits/tokens between
    a ``lut4`` plan and an ``int_sim`` plan off-TPU, and between the kernel
    and its twin on-TPU (integer accumulation is exact)."""
    xf = x2.astype(jnp.float32)
    w_scale = quant_scale(w, axis=0, bits=cfg.w_bits)    # [1, N]
    w_q = quantize(w, w_scale, bits=cfg.w_bits)
    a_scale = quant_scale(xf, axis=1, bits=cfg.a_bits)   # per-row
    a_q = quantize(xf, a_scale, bits=cfg.a_bits)
    # the table kernel is int4-specific; other bit widths keep the XLA path
    if ops.use_pallas() and cfg.a_bits == 4 and cfg.w_bits == 4:
        return ops.lut4_matmul_kmajor(a_q, a_scale, pack_kmajor(w_q),
                                      w_scale, tag=tag)
    acc = jnp.dot(a_q, w_q, preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * a_scale * w_scale


@register_backend("w4a16")
def _w4a16_backend(w, x2, cfg, tag):
    """Weight-only serving: activation-dtype MXU contraction with scales in
    the epilogue (kernels.ops.w4a16_matmul on TPU, XLA twin elsewhere)."""
    g = cfg.group_size if cfg.group_size else w.shape[0]
    w_q, w_scale = group_quantize(w, g, bits=cfg.w_bits)
    if ops.use_pallas() and cfg.w_bits == 4:
        rm = 2 * g if w_scale.ndim == 3 else 2
        return ops.w4a16_matmul_kmajor(x2, pack_kmajor(w_q, rm), w_scale, g,
                                       tag=tag)
    wf = group_dequantize(w_q, w_scale, g)
    return jnp.dot(x2.astype(jnp.float32), wf,
                   preferred_element_type=jnp.float32)


@register_backend("netlist")
def _netlist_backend(w, x2, cfg, tag):
    """End-to-end oracle: every 4-bit product through the simulated FPGA
    circuit (the paper's netlist).  O(bits) slower; tests / tiny shapes."""
    from .mult4_proposed import build_proposed_mult4

    nl = build_proposed_mult4()
    xf = x2.astype(jnp.float32)
    a_scale = quant_scale(xf, axis=1, bits=cfg.a_bits)
    a_q = quantize(xf, a_scale, bits=cfg.a_bits)             # [M, K]
    w_scale = quant_scale(w, axis=0, bits=cfg.w_bits)
    w_q = quantize(w, w_scale, bits=cfg.w_bits)              # [K, N]
    mag_a, sign_a = to_unsigned_mag(a_q)
    mag_w, sign_w = to_unsigned_mag(w_q)
    # products [M, K, N] through the netlist (vectorized over all pairs)
    prod = nl(mag_a[:, :, None], mag_w[None, :, :]).astype(jnp.int32)
    prod = prod * sign_a[:, :, None] * sign_w[None, :, :]
    acc = jnp.sum(prod, axis=1).astype(jnp.float32)
    return acc * a_scale * w_scale
