"""Baseline exact 4-bit multipliers (paper §III / Tables II-III comparison set).

Implemented as netlists (exhaustively verified):

* ``lm``  -- the prior 12-LUT / 1-CARRY4 design point of Yao & Zhang [1].
  The excerpt does not publish LM's internal netlist, so we re-implement it at
  its published resource point: the same column-compression front end as the
  proposed design, but with the top product bit taken as CO[3] routed through
  the general fabric into a pass-through LUT (the slow path the paper calls
  out), i.e. proposed-minus-the-chain-B-trick: 12 LUTs + 1 CARRY4.

* ``acc_ullah`` -- reconstruction of Ullah et al. [2]: two exact 4x2
  multipliers (each 5 LUTs + 1 CARRY4) plus a 6-bit carry-chain final adder
  (6 LUTs + 2 CARRY4).  Our reconstruction lands at 16 LUTs / 4 CARRY4 vs the
  published 15 / 3 (they share one LUT and pack the chains tighter); both
  numbers are reported in benchmarks with provenance columns.

* ``behavioral`` -- the ``p = a * b`` RTL description (pure jnp multiply);
  resources/CPD for its two synthesis strategies are published-data-only rows.

Literature rows [3][4][5][6] and Vivado IP are data-only (`PUBLISHED_ROWS`).
"""

from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

from .mult4_proposed import build_proposed_mult4
from .netlist import CONST0, CONST1, Carry4, Lut, Netlist


def build_lm_mult4() -> Netlist:
    """12-LUT / 1-CARRY4 design point (LM [1] resource-equivalent)."""
    base = build_proposed_mult4()
    cells = [c for c in base.cells if c.name not in ("CarryChainA", "CarryChainB")]
    chain = Carry4(
        name="CarryChainA",
        s=["Prop0", "Prop1", "Prop2", "Prop3"],
        di=["Gen0", "Gen1", "Gen2", "Gen3"],
        cin="C0",
        o_out=["P3", "P4", "P5", "P6"],
        co_out=[None, None, None, "CO3A"],
    )
    # P7: CO[3] must traverse the neighbouring CARRY4 and the general routing
    # fabric to reach a LUT (paper §II last paragraph) -- modelled by the
    # timing engine via the `from_co_fabric` edge class.
    p7lut = Lut(
        name="LUT12_P7",
        inputs=["CO3A", CONST1, CONST1, CONST1, CONST1, CONST1],
        fn_o6=lambda v: v["CO3A"],
        out_o6="P7",
    )
    return Netlist(
        name="lm",
        inputs=base.inputs,
        outputs=base.outputs,
        cells=cells + [chain, p7lut],
    )


def _build_mult4x2(prefix: str, b_lo: str, b_hi: str) -> list:
    """Exact 4x2 multiplier: A[3:0] * (b_hi,b_lo) -> m0..m5 (5 LUTs + 1 CARRY4)."""
    A = [f"A{i}" for i in range(4)]
    m = [f"{prefix}m{i}" for i in range(6)]
    lut_lo = Lut(
        name=f"{prefix}LUTlo",
        inputs=[A[0], A[1], b_lo, b_hi, CONST1, CONST1],
        fn_o6=lambda v, bl=b_lo, bh=b_hi: (v["A1"] & v[bl]) ^ (v["A0"] & v[bh]),
        out_o6=m[1],
        fn_o5=lambda v, bl=b_lo: v["A0"] & v[bl],
        out_o5=m[0],
    )
    lut_c1 = Lut(
        name=f"{prefix}LUTc1",
        inputs=[A[0], A[1], b_lo, b_hi, CONST1, CONST1],
        fn_o6=lambda v, bl=b_lo, bh=b_hi: (v["A1"] & v[bl]) & (v["A0"] & v[bh]),
        out_o6=f"{prefix}c1",
    )
    lut_s0 = Lut(
        name=f"{prefix}LUTs0",
        inputs=[A[1], A[2], b_lo, b_hi, CONST1, CONST1],
        fn_o6=lambda v, bl=b_lo, bh=b_hi: (v["A2"] & v[bl]) ^ (v["A1"] & v[bh]),
        out_o6=f"{prefix}p2",
        fn_o5=lambda v, bl=b_lo, bh=b_hi: (v["A2"] & v[bl]) & (v["A1"] & v[bh]),
        out_o5=f"{prefix}g2",
    )
    lut_s1 = Lut(
        name=f"{prefix}LUTs1",
        inputs=[A[2], A[3], b_lo, b_hi, CONST1, CONST1],
        fn_o6=lambda v, bl=b_lo, bh=b_hi: (v["A3"] & v[bl]) ^ (v["A2"] & v[bh]),
        out_o6=f"{prefix}p3",
        fn_o5=lambda v, bl=b_lo, bh=b_hi: (v["A3"] & v[bl]) & (v["A2"] & v[bh]),
        out_o5=f"{prefix}g3",
    )
    lut_s2 = Lut(
        name=f"{prefix}LUTs2",
        inputs=[A[3], b_hi, CONST1, CONST1, CONST1, CONST1],
        fn_o6=lambda v, bh=b_hi: v["A3"] & v[bh],
        out_o6=f"{prefix}p4",
    )
    chain = Carry4(
        name=f"{prefix}Chain",
        s=[f"{prefix}p2", f"{prefix}p3", f"{prefix}p4", CONST0],
        di=[f"{prefix}g2", f"{prefix}g3", CONST0, CONST0],
        cin=f"{prefix}c1",
        o_out=[m[2], m[3], m[4], m[5]],
        co_out=[None, None, None, None],
    )
    return [lut_lo, lut_c1, lut_s0, lut_s1, lut_s2, chain]


def build_acc_mult4() -> Netlist:
    """Reconstruction of Acc [2]: two 4x2 multipliers + carry-chain adder."""
    lo = _build_mult4x2("L", "B0", "B1")
    hi = _build_mult4x2("H", "B2", "B3")
    # Final add: P = L + (H << 2); P0/P1 pass straight through.
    add_luts = []
    for i in range(4):
        add_luts.append(
            Lut(
                name=f"ADDp{i}",
                inputs=[f"Lm{i+2}", f"Hm{i}", CONST1, CONST1, CONST1, CONST1],
                fn_o6=lambda v, l=f"Lm{i+2}", h=f"Hm{i}": v[l] ^ v[h],
                out_o6=f"ap{i}",
                fn_o5=lambda v, l=f"Lm{i+2}", h=f"Hm{i}": v[l] & v[h],
                out_o5=f"ag{i}",
            )
        )
    # pass LUTs for the two top bits (S pin must come from a LUT O6)
    for j, src in ((4, "Hm4"), (5, "Hm5")):
        add_luts.append(
            Lut(
                name=f"ADDpass{j}",
                inputs=[src, CONST1, CONST1, CONST1, CONST1, CONST1],
                fn_o6=lambda v, s=src: v[s],
                out_o6=f"ap{j}",
            )
        )
    chain1 = Carry4(
        name="AddChain1",
        s=["ap0", "ap1", "ap2", "ap3"],
        di=["ag0", "ag1", "ag2", "ag3"],
        cin=CONST0,
        o_out=["P2", "P3", "P4", "P5"],
        co_out=[None, None, None, "addco3"],
    )
    chain2 = Carry4(
        name="AddChain2",
        s=["ap4", "ap5", CONST0, CONST0],
        di=[CONST0, CONST0, CONST0, CONST0],
        cin="addco3",
        o_out=["P6", "P7", None, None],
        co_out=[None, None, None, None],
        cin_dedicated=True,
    )
    # rename L's m0/m1 to P0/P1 via output aliasing: evaluate then map.
    alias0 = Lut(
        name="AliasP0",
        inputs=["Lm0", CONST1, CONST1, CONST1, CONST1, CONST1],
        fn_o6=lambda v: v["Lm0"],
        out_o6="P0",
    )
    alias1 = Lut(
        name="AliasP1",
        inputs=["Lm1", CONST1, CONST1, CONST1, CONST1, CONST1],
        fn_o6=lambda v: v["Lm1"],
        out_o6="P1",
    )
    # NOTE: alias LUTs exist only so `outputs` resolve uniformly; they are
    # excluded from the LUT count (a real design renames the net).
    nl = Netlist(
        name="acc_ullah",
        inputs=[f"A{i}" for i in range(4)] + [f"B{i}" for i in range(4)],
        outputs=[f"P{i}" for i in range(8)],
        cells=lo + hi + add_luts + [chain1, chain2, alias0, alias1],
    )
    nl.alias_luts = ("AliasP0", "AliasP1")  # type: ignore[attr-defined]
    return nl


def behavioral_mult4(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """The `p = a*b` RTL description (paper's "Exact" baseline)."""
    return (jnp.asarray(a, jnp.uint32) * jnp.asarray(b, jnp.uint32)).astype(jnp.uint8)


#: Published rows for designs we do not re-implement (paper Tables II/III).
PUBLISHED_ROWS: Dict[str, Dict[str, object]] = {
    "proposed": dict(luts=11, carry4=2, cpd=2.750, logic=1.302, net=1.448),
    "lm": dict(luts=12, carry4=1, cpd=3.299, logic=1.910, net=1.389),
    "acc_ullah": dict(luts=15, carry4=3, cpd=3.979, logic=1.978, net=2.001),
    "smapproxlib_ullah18": dict(luts=12, carry4=3, cpd=None, logic=None, net=None),
    "rehman16": dict(luts=16, carry4=0, cpd=None, logic=None, net=None),
    "wang23": dict(luts=13, carry4=4, cpd=None, logic=None, net=None),
    "loam_guo24": dict(luts=13, carry4=1, cpd=3.301, logic=1.555, net=1.746),
    "exact_area_opt": dict(luts=15, carry4=2, cpd=2.728, logic=1.259, net=1.469),
    "exact_perf_opt": dict(luts=20, carry4=2, cpd=2.533, logic=1.224, net=1.309),
    "vivado_ip_area_opt": dict(luts=13, carry4=2, cpd=3.739, logic=1.607, net=2.132),
    "vivado_ip_perf_opt": dict(luts=15, carry4=2, cpd=3.393, logic=1.586, net=1.807),
}
