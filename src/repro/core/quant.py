"""int4 quantization stack — the framework-level embodiment of the paper's
"dense arrays of 4-bit multipliers for edge inference" motivation (§I).

Symmetric signed-int4 quantization (q in [-8, 7], scale = amax/7) with
per-tensor / per-channel / per-group granularity, straight-through-estimator
fake-quant for QAT, and nibble packing (two int4 lanes per uint8 byte) for the
serving path consumed by ``repro.kernels``.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

INT4_MIN, INT4_MAX = -8, 7


def _qrange(bits: int) -> Tuple[int, int]:
    return -(2 ** (bits - 1)), 2 ** (bits - 1) - 1


def quant_scale(
    x: jnp.ndarray, axis: Optional[int] = None, bits: int = 4, eps: float = 1e-8
) -> jnp.ndarray:
    """Symmetric scale; `axis=None` -> per-tensor, else reduce over `axis`."""
    _, qmax = _qrange(bits)
    if axis is None:
        amax = jnp.max(jnp.abs(x))
    else:
        amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    return jnp.maximum(amax, eps) / qmax


def quantize(x: jnp.ndarray, scale: jnp.ndarray, bits: int = 4) -> jnp.ndarray:
    qmin, qmax = _qrange(bits)
    q = jnp.clip(jnp.round(x / scale), qmin, qmax)
    return q.astype(jnp.int8)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(scale.dtype) * scale


def fake_quant(
    x: jnp.ndarray, axis: Optional[int] = None, bits: int = 4
) -> jnp.ndarray:
    """Quantize-dequantize with a straight-through-estimator gradient (QAT).

    Scale/grid math runs in fp32 but the result keeps x.dtype, so bf16
    activations stay bf16 through the STE (otherwise every TP all-reduce in
    the backward doubles to fp32 width — a measured §Perf regression).
    """
    x32 = x.astype(jnp.float32)
    scale = quant_scale(x32, axis=axis, bits=bits)
    xq = dequantize(quantize(x32, scale, bits=bits), scale).astype(x.dtype)
    return x + jax.lax.stop_gradient(xq - x)


def group_quantize(
    w: jnp.ndarray, group_size: int, bits: int = 4
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-group quantization along the *first* (reduction) axis of w [K, N].

    Returns (q [K, N] int8-with-int4-values, scales [K//G, 1, N]).
    """
    K, N = w.shape
    if group_size <= 0 or group_size >= K:
        scale = quant_scale(w, axis=0, bits=bits)          # per-output-channel
        return quantize(w, scale, bits=bits), scale
    assert K % group_size == 0, (K, group_size)
    wg = w.reshape(K // group_size, group_size, N)
    scale = quant_scale(wg, axis=1, bits=bits)
    q = quantize(wg, scale, bits=bits).reshape(K, N)
    return q, scale


def group_dequantize(
    q: jnp.ndarray, scale: jnp.ndarray, group_size: int
) -> jnp.ndarray:
    K, N = q.shape
    if scale.ndim == 2:                                    # per-channel
        return dequantize(q, scale)
    qg = q.reshape(K // group_size, group_size, N)
    return dequantize(qg, scale).reshape(K, N)


# ---------------------------------------------------------------------------
# Nibble packing: the serving-side memory format.  Two signed int4 values per
# uint8 byte, packed along the given axis (must have even length).  This is
# the TPU analogue of the paper's area argument: 4-bit packing halves weight
# bytes vs int8 and quarters them vs bf16, directly scaling the achievable
# "multiplier array" per unit of HBM bandwidth.
# ---------------------------------------------------------------------------

def pack_int4(q: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Pack int8 tensor holding int4 values in [-8,7] into uint8 nibbles."""
    q = jnp.moveaxis(q, axis, -1)
    assert q.shape[-1] % 2 == 0, q.shape
    lo = q[..., 0::2] & 0xF
    hi = q[..., 1::2] & 0xF
    packed = (lo | (hi << 4)).astype(jnp.uint8)
    return jnp.moveaxis(packed, -1, axis)


def unpack_int4(p: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Inverse of pack_int4: uint8 nibbles -> int8 tensor of int4 values."""
    p = jnp.moveaxis(p, axis, -1)
    lo = (p & 0xF).astype(jnp.int8)
    hi = ((p >> 4) & 0xF).astype(jnp.int8)
    # sign-extend 4-bit two's complement: (n ^ 8) - 8
    lo = ((lo ^ 8) - 8).astype(jnp.int8)
    hi = ((hi ^ 8) - 8).astype(jnp.int8)
    out = jnp.stack([lo, hi], axis=-1).reshape(*p.shape[:-1], p.shape[-1] * 2)
    return jnp.moveaxis(out, -1, axis)


def to_unsigned_mag(q: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Split signed int4 values into (|q| in [0,8], sign in {-1,+1}).

    |q| <= 8 fits the unsigned 4-bit domain of the paper's multiplier, so the
    netlist computes |a|*|b| exactly and the sign is applied afterwards.
    """
    sign = jnp.where(q < 0, jnp.int32(-1), jnp.int32(1))
    return jnp.abs(q.astype(jnp.int32)).astype(jnp.uint8), sign
