"""Core: the paper's contribution — exact 4-bit multiplier netlists, their
area/timing models, and the int4 quantization stack built on top of them."""

from .netlist import Carry4, Lut, Netlist, CONST0, CONST1  # noqa: F401
from .mult4_proposed import build_proposed_mult4  # noqa: F401
from .mult4_baselines import (  # noqa: F401
    PUBLISHED_ROWS,
    behavioral_mult4,
    build_acc_mult4,
    build_lm_mult4,
)
from .timing import ARTIX7_CALIBRATED, DelayModel, analyze  # noqa: F401
from .area import resources  # noqa: F401
