"""QuantizedLinear: the paper's technique as a first-class framework feature.

Every projection in every architecture config routes through `qdense`.  The
backend is selected by `QuantConfig.backend`:

  float       -- plain bf16/f32 GEMM (reference / ablation baseline)
  fake_quant  -- QAT: STE fake-quant on weights (per-out-channel) and
                 activations (per-tensor dynamic); float GEMM. Training mode.
  int_sim     -- W4A4 integer GEMM in XLA (int8 dot, int32 accum, dequant
                 epilogue): identical math to kernels/int4_matmul.py, usable
                 inside multi-device pjit graphs (dry-run / CPU).
  pallas_int4 -- kernels.ops.int4_matmul_fused: quantize + int8-MXU matmul +
                 dequant in one pallas_call (real TPU path; XLA twin math
                 on CPU/GPU — see kernels.ops dispatch).
  w4a16       -- weight-only serving: kernels.ops.w4a16_matmul (activation-
                 dtype MXU contraction, scales in the epilogue; XLA twin
                 elsewhere).  Tile shapes come from kernels.autotune.
  netlist     -- bit-exact FPGA-netlist simulation of every 4-bit product
                 (the paper's circuit, used as the end-to-end oracle; O(bits)
                 slower, tests / tiny shapes only).

Weights are stored as float master copies (training) — serving-time packing is
done once by `pack_params`.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.packing import pack_kmajor, prepack_kmajor
from .mult4_proposed import build_proposed_mult4
from .quant import (
    fake_quant,
    pack_int4,
    quant_scale,
    quantize,
    to_unsigned_mag,
    unpack_int4,
)


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    backend: str = "fake_quant"     # float | fake_quant | int_sim | pallas_int4 | w4a16 | netlist
    w_bits: int = 4
    a_bits: int = 4
    group_size: int = 0             # 0 => per-output-channel scales
    quantize_embedding: bool = False

    @property
    def quantized(self) -> bool:
        return self.backend != "float"


FLOAT = QuantConfig(backend="float")
QAT_W4A4 = QuantConfig(backend="fake_quant")
INT_SIM_W4A4 = QuantConfig(backend="int_sim")


def _flatten_batch(x: jnp.ndarray):
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


def qdense(
    w,                              # [K, N] float master weight OR packed dict
    x: jnp.ndarray,                 # [..., K]
    cfg: QuantConfig,
    bias: Optional[jnp.ndarray] = None,
    tag: str = "",
) -> jnp.ndarray:
    """Quantized dense layer. Output dtype follows x.

    `w` may be a pre-packed serving weight (`{"packed": uint8 [K, N/2],
    "scale": f32 [1, N]}`, from `pack_tree`): weight bytes drop 4x vs bf16 —
    the paper's area argument at system level.  Packed backends:
    `w4a16_packed` (dequant + bf16 GEMM) and `w4a4_packed` (dynamic per-token
    int4 activations + int8 GEMM + int32 accum, the full technique).

    `tag` names the call site (e.g. "ffn.w_in"): it keys per-deployment-shape
    tile tuning in `kernels.autotune`, so the same GEMM shape can carry
    different tuned blocks at different sites.  Kernel-backed GEMMs run
    through the Pallas kernels on TPU and their XLA twins elsewhere
    (`ops` dispatch) — identical math either way.
    """
    if isinstance(w, dict) and "packed" in w:
        return _qdense_packed(w, x, cfg, bias, tag)
    if cfg.backend in ("w4a4_packed", "w4a16_packed"):
        # weight not packed (too small / excluded by pack_tree): equivalent
        # on-the-fly path
        cfg = dataclasses.replace(
            cfg, backend="int_sim" if cfg.backend == "w4a4_packed" else "w4a16")
    out_dtype = x.dtype
    if cfg.backend == "float":
        y = jnp.einsum("...k,kn->...n", x, w.astype(x.dtype))
    elif cfg.backend == "fake_quant":
        wq = fake_quant(w, axis=0, bits=cfg.w_bits)          # per-out-channel
        # per-token activation scales: keeps prefill/decode bit-consistent
        xq = fake_quant(x, axis=-1, bits=cfg.a_bits)         # stays x.dtype
        y = jnp.einsum("...k,kn->...n", xq, wq.astype(x.dtype))
    elif cfg.backend in ("int_sim", "pallas_int4"):
        x2, lead = _flatten_batch(x.astype(jnp.float32))
        w_scale = quant_scale(w, axis=0, bits=cfg.w_bits)    # [1, N]
        w_q = quantize(w, w_scale, bits=cfg.w_bits)
        # the Pallas kernels are int4-specific; other bit widths keep the
        # XLA path so cfg.a_bits/w_bits are honored on every backend
        if cfg.backend == "pallas_int4" and ops.use_pallas() \
                and cfg.a_bits == 4 and cfg.w_bits == 4:
            # quantize + matmul + dequant in one pallas_call; the weight is
            # packed K-major directly from the quantized master (no
            # interleaved round-trip)
            y = ops.int4_matmul_fused_kmajor(
                x2, pack_kmajor(w_q), w_scale, tag=tag)
        else:
            a_scale = quant_scale(x2, axis=1, bits=cfg.a_bits)  # per-row
            a_q = quantize(x2, a_scale, bits=cfg.a_bits)
            acc = jnp.dot(a_q, w_q, preferred_element_type=jnp.int32)
            y = acc.astype(jnp.float32) * a_scale * w_scale
        y = y.reshape(*lead, w.shape[1])
    elif cfg.backend == "w4a16":
        from .quant import group_dequantize, group_quantize

        x2, lead = _flatten_batch(x)
        g = cfg.group_size if cfg.group_size else w.shape[0]
        w_q, w_scale = group_quantize(w, g, bits=cfg.w_bits)
        if ops.use_pallas() and cfg.w_bits == 4:
            rm = 2 * g if w_scale.ndim == 3 else 2
            y = ops.w4a16_matmul_kmajor(x2, pack_kmajor(w_q, rm), w_scale, g,
                                        tag=tag)
        else:
            wf = group_dequantize(w_q, w_scale, g)
            y = jnp.dot(x2.astype(jnp.float32), wf,
                        preferred_element_type=jnp.float32)
        y = y.reshape(*lead, w.shape[1])
    elif cfg.backend == "netlist":
        y = _netlist_matmul(w, x, cfg)
    else:
        raise ValueError(cfg.backend)
    if bias is not None:
        y = y + bias.astype(y.dtype)
    return y.astype(out_dtype)


def _netlist_matmul(w: jnp.ndarray, x: jnp.ndarray, cfg: QuantConfig) -> jnp.ndarray:
    """End-to-end oracle: every 4-bit product through the simulated circuit."""
    nl = build_proposed_mult4()
    x2, lead = _flatten_batch(x.astype(jnp.float32))
    a_scale = quant_scale(x2, axis=1, bits=cfg.a_bits)
    a_q = quantize(x2, a_scale, bits=cfg.a_bits)             # [M, K]
    w_scale = quant_scale(w, axis=0, bits=cfg.w_bits)
    w_q = quantize(w, w_scale, bits=cfg.w_bits)              # [K, N]
    mag_a, sign_a = to_unsigned_mag(a_q)
    mag_w, sign_w = to_unsigned_mag(w_q)
    # products [M, K, N] through the netlist (vectorized over all pairs)
    prod = nl(mag_a[:, :, None], mag_w[None, :, :]).astype(jnp.int32)
    prod = prod * sign_a[:, :, None] * sign_w[None, :, :]
    acc = jnp.sum(prod, axis=1).astype(jnp.float32)
    y = acc * a_scale * w_scale
    return y.reshape(*lead, w.shape[1])


def pack_params(w: jnp.ndarray, cfg: QuantConfig):
    """One-time serving-side packing of a float weight into (uint8, scales)."""
    from .quant import group_quantize

    g = cfg.group_size if cfg.group_size else w.shape[0]
    w_q, w_scale = group_quantize(w, g, bits=cfg.w_bits)
    return pack_int4(w_q, axis=-1), w_scale


def _qdense_packed(w, x, cfg: QuantConfig, bias, tag: str = ""):
    """Serving path: `w` from pack_tree / pack_weight_nd.

    On Pallas backends the GEMM runs through the kernels (W4A4: fused
    activation-quantize; W4A16: per-channel epilogue kernel) using the
    `packed_km` planar weight when `prepack_tree` added one (else the
    interleaved weight is relayouted in-graph).  Elsewhere: XLA twins."""
    out_dtype = x.dtype
    packed, w_scale = w["packed"], w["scale"]
    # packed weights are int4 by pack_tree construction; int_sim keeps its
    # documented pure-XLA/pjit contract even on Pallas backends, and
    # non-int4 activation configs keep the XLA path (a_bits honored)
    kernel_ok = ops.use_pallas() and packed.ndim == 2
    if cfg.backend in ("w4a4_packed", "int_sim", "pallas_int4"):
        x2, lead = _flatten_batch(x.astype(jnp.float32))
        if kernel_ok and cfg.backend != "int_sim" and cfg.a_bits == 4:
            w_km = w.get("packed_km")
            if w_km is None:
                w_km = prepack_kmajor(packed)
            y = ops.int4_matmul_fused_kmajor(x2, w_km, w_scale, tag=tag)
            n_out = w_km.shape[1]
        else:
            a_scale = quant_scale(x2, axis=1, bits=cfg.a_bits)
            a_q = quantize(x2, a_scale, bits=cfg.a_bits)
            w_q = unpack_int4(packed, axis=-1)
            acc = jnp.dot(a_q, w_q, preferred_element_type=jnp.int32)
            y = acc.astype(jnp.float32) * a_scale * w_scale
            n_out = w_q.shape[1]
        y = y.reshape(*lead, n_out)
    elif kernel_ok:                     # w4a16_packed through the kernel
        x2, lead = _flatten_batch(x)
        w_km = w.get("packed_km")
        if w_km is None:
            w_km = prepack_kmajor(packed)
        # pack_weight_nd scales are per-output-channel [1, N]
        y = ops.w4a16_matmul_kmajor(x2, w_km, w_scale, x2.shape[1], tag=tag)
        y = y.reshape(*lead, w_km.shape[1])
    else:                               # w4a16_packed: dequant + bf16 GEMM
        w_q = unpack_int4(packed, axis=-1)
        wf = (w_q.astype(jnp.float32) * w_scale).astype(x.dtype)
        y = jnp.einsum("...k,kn->...n", x, wf)
    if bias is not None:
        y = y + bias.astype(y.dtype)
    return y.astype(out_dtype)


#: linear-weight leaf names eligible for serving-side packing (allowlist).
PACKABLE_NAMES = frozenset({
    "wq", "wk", "wv", "wo",                  # attention projections
    "w_in", "w_gate", "w_out",               # FFN / MoE experts
    "in_proj", "out_proj",                   # mamba
    "in_x", "in_g", "w_a", "w_x", "out",     # rg-lru
})


def pack_weight_nd(w: jnp.ndarray, cfg: QuantConfig):
    """Pack a [..., K, N] float weight: int4 per-output-channel (scale over
    the K axis), nibbles packed along N.  Works for plain [K,N], layer-
    stacked [L,K,N] and stacked experts [L,E,K,N]."""
    scale = quant_scale(w, axis=-2, bits=cfg.w_bits)          # [..., 1, N]
    q = quantize(w, scale, bits=cfg.w_bits)
    return {"packed": pack_int4(q, axis=-1), "scale": scale}


def prepack_tree(params):
    """Add a `packed_km` planar K-major twin to every packed serving weight
    (see kernels/packing.py).  One-time, serving-side: the Pallas kernels
    then unpack with a shift/mask only — no per-step relayout.  No-op on
    unpacked leaves; safe to call on any pack_tree output.

    MoE expert weights are skipped: they run through the batched einsum in
    models/moe.py, never the 2D kernels, so a twin would just double their
    footprint for the whole serving lifetime."""
    import jax

    from repro.kernels.packing import nmajor_to_kmajor

    def maybe(path, d):
        in_experts = any(
            str(getattr(p, "key", "")) == "experts" for p in path)
        if isinstance(d, dict) and "packed" in d and "packed_km" not in d \
                and not in_experts:
            return {**d, "packed_km": nmajor_to_kmajor(d["packed"])}
        return d

    return jax.tree_util.tree_map_with_path(
        maybe, params, is_leaf=lambda n: isinstance(n, dict) and "packed" in n)


def pack_tree(params, cfg: QuantConfig, min_size: int = 1 << 12):
    """Convert linear weights (by allowlisted name) into the packed serving
    format.  Norms, biases, convs, embeddings, routers stay float."""
    import jax

    def maybe_pack(path, leaf):
        name = str(getattr(path[-1], "key", getattr(path[-1], "idx", path[-1])))
        packable = (
            name in PACKABLE_NAMES
            and leaf.ndim >= 2
            and leaf.size >= min_size
            and leaf.shape[-1] % 2 == 0
            and leaf.dtype in (jnp.float32, jnp.bfloat16)
        )
        if not packable:
            return leaf
        return pack_weight_nd(leaf.astype(jnp.float32), cfg)

    return jax.tree_util.tree_map_with_path(maybe_pack, params)
