"""QuantizedLinear: the paper's technique as a first-class framework feature.

Every projection in every architecture config routes through `qdense`.  The
backend is selected by `QuantConfig.backend` out of the registry in
`core.backends` (one function per backend, registered by name — new backends
are additions, not edits):

  float       -- plain bf16/f32 GEMM (reference / ablation baseline)
  fake_quant  -- QAT: STE fake-quant on weights (per-out-channel) and
                 activations (per-tensor dynamic); float GEMM. Training mode.
  int_sim     -- W4A4 integer GEMM in XLA (int8 dot, int32 accum, dequant
                 epilogue): identical math to kernels/int4_matmul.py, usable
                 inside multi-device pjit graphs (dry-run / CPU).
  pallas_int4 -- kernels.ops.int4_matmul_fused: quantize + int8-MXU matmul +
                 dequant in one pallas_call (real TPU path; XLA twin math
                 on CPU/GPU — see kernels.ops dispatch).
  w4a16       -- weight-only serving: kernels.ops.w4a16_matmul (activation-
                 dtype MXU contraction, scales in the epilogue; XLA twin
                 elsewhere).  Tile shapes come from kernels.autotune.
  lut4        -- W4A4 through the paper's LUT multiplier amortized across a
                 GEMM tile: kernels.ops.lut4_matmul (per-nibble product
                 tables + lane-dim take, MXU-free int32 accumulation; XLA
                 twin is the same int8 dot as int_sim — bit-identical).
  netlist     -- bit-exact FPGA-netlist simulation of every 4-bit product
                 (the paper's circuit, used as the end-to-end oracle; O(bits)
                 slower, tests / tiny shapes only).

Which backend runs at which call site is decided by the active QuantPlan
(`core.quant_plan`): the `tag`/site string each model layer passes names the
call site, and `Runtime.quant_cfg(arch, site)` resolves it to a per-site
QuantConfig before calling qdense.

Weights are stored as float master copies (training) — serving-time packing
is done once by `pack_params`/`quant_plan.plan_pack_tree`.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.packing import prepack_kmajor
from .quant import pack_int4, quant_scale, quantize, unpack_int4


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    backend: str = "fake_quant"     # float | fake_quant | int_sim | pallas_int4 | lut4 | w4a16 | netlist
    w_bits: int = 4
    a_bits: int = 4
    group_size: int = 0             # 0 => per-output-channel scales
    quantize_embedding: bool = False

    @property
    def quantized(self) -> bool:
        return self.backend != "float"


FLOAT = QuantConfig(backend="float")
QAT_W4A4 = QuantConfig(backend="fake_quant")
INT_SIM_W4A4 = QuantConfig(backend="int_sim")


def _flatten_batch(x: jnp.ndarray):
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


def qdense(
    w,                              # [K, N] float master weight OR packed dict
    x: jnp.ndarray,                 # [..., K]
    cfg: QuantConfig,
    bias: Optional[jnp.ndarray] = None,
    tag: str = "",
) -> jnp.ndarray:
    """Quantized dense layer. Output dtype follows x.

    `w` may be a pre-packed serving weight (`{"packed": uint8 [K, N/2],
    "scale": [1, N]}`, from `quant_plan.plan_pack_tree`): weight bytes drop
    4x vs bf16 — the paper's area argument at system level.  Packed backends:
    `w4a16_packed` (dequant + bf16 GEMM) and `w4a4_packed` (dynamic per-token
    int4 activations + int8 GEMM + int32 accum, the full technique).

    `tag` names the call site (e.g. "block[3].ffn.w_in"): the same string
    keys the per-site backend choice in the active QuantPlan *and*
    per-deployment-shape tile tuning in `kernels.autotune`, so the same GEMM
    shape can carry different tuned blocks at different sites.  Kernel-backed
    GEMMs run through the Pallas kernels on TPU and their XLA twins elsewhere
    (`ops` dispatch) — identical math either way.

    The shared wrapper here owns batch flattening, the reshape epilogue,
    bias add and output-dtype cast; the per-backend GEMMs live in
    `core.backends` (registry — see `register_backend`).
    """
    from .backends import get_backend

    if isinstance(w, dict) and "packed" in w:
        fn = _packed_backend
    else:
        if cfg.backend in ("w4a4_packed", "w4a16_packed"):
            # weight not packed (too small / excluded by the plan packer):
            # equivalent on-the-fly path
            cfg = dataclasses.replace(
                cfg,
                backend="int_sim" if cfg.backend == "w4a4_packed" else "w4a16")
        fn = get_backend(cfg.backend)
    out_dtype = x.dtype
    x2, lead = _flatten_batch(x)
    y = fn(w, x2, cfg, tag)
    y = y.reshape(*lead, y.shape[-1])
    if bias is not None:
        y = y + bias.astype(y.dtype)
    return y.astype(out_dtype)


def pack_params(w: jnp.ndarray, cfg: QuantConfig):
    """One-time serving-side packing of a float weight into (uint8, scales)."""
    from .quant import group_quantize

    g = cfg.group_size if cfg.group_size else w.shape[0]
    w_q, w_scale = group_quantize(w, g, bits=cfg.w_bits)
    return pack_int4(w_q, axis=-1), w_scale


def _packed_backend(w, x2, cfg: QuantConfig, tag: str = ""):
    """Serving path: `w` from plan_pack_tree / pack_weight_nd.

    On Pallas backends the GEMM runs through the kernels (W4A4: fused
    activation-quantize; W4A16: per-channel epilogue kernel) using the
    `packed_km` planar weight when `prepack_tree` added one (else the
    interleaved weight is relayouted in-graph).  Elsewhere: XLA twins."""
    packed, w_scale = w["packed"], w["scale"]
    # packed weights are int4 by construction; int_sim keeps its documented
    # pure-XLA/pjit contract even on Pallas backends, and non-int4
    # activation configs keep the XLA path (a_bits honored)
    kernel_ok = ops.use_pallas() and packed.ndim == 2
    if cfg.backend in ("w4a4_packed", "int_sim", "pallas_int4", "lut4"):
        xf = x2.astype(jnp.float32)
        if kernel_ok and cfg.backend == "lut4" and cfg.a_bits == 4:
            # table-lookup kernel: weights stay packed in-kernel (the tables
            # index the planar byte directly), activations quantized here
            w_km = w.get("packed_km")
            if w_km is None:
                w_km = prepack_kmajor(packed)
            a_scale = quant_scale(xf, axis=1, bits=4)
            a_q = quantize(xf, a_scale, bits=4)
            return ops.lut4_matmul_kmajor(a_q, a_scale, w_km, w_scale,
                                          tag=tag)
        if kernel_ok and cfg.backend not in ("int_sim", "lut4") \
                and cfg.a_bits == 4:
            w_km = w.get("packed_km")
            if w_km is None:
                w_km = prepack_kmajor(packed)
            return ops.int4_matmul_fused_kmajor(xf, w_km, w_scale, tag=tag)
        a_scale = quant_scale(xf, axis=1, bits=cfg.a_bits)
        a_q = quantize(xf, a_scale, bits=cfg.a_bits)
        w_q = unpack_int4(packed, axis=-1)
        acc = jnp.dot(a_q, w_q, preferred_element_type=jnp.int32)
        return acc.astype(jnp.float32) * a_scale * w_scale
    if cfg.backend not in ("w4a16", "w4a16_packed"):
        # a packed weight reaching a backend with no packed path used to
        # fall through to the w4a16 dequant branch silently — wrong math
        # for anything that isn't weight-only.  Loud beats lenient: the
        # plan/manifest checks in checkpoint.restore_quantized keep a legal
        # configuration from ever landing here.
        raise ValueError(
            f"packed weight at site {tag!r} reached backend "
            f"{cfg.backend!r}, which has no packed-weight path; restore "
            f"with the plan the checkpoint was packed under (the manifest "
            f"records per-site backends) or rebuild from float masters")
    # w4a16 / w4a16_packed: pack_weight_nd scales are per-output-channel
    # [1, N] or per-group [K//G, 1, N] — the group size is recovered from
    # the scale shape
    K = x2.shape[1]
    g = K // w_scale.shape[0] if w_scale.ndim == 3 else K
    if kernel_ok:                       # via the epilogue kernel
        w_km = w.get("packed_km")
        if w_km is None:
            w_km = prepack_kmajor(packed, 2 * g if w_scale.ndim == 3 else 2)
        return ops.w4a16_matmul_kmajor(x2, w_km, w_scale, g, tag=tag)
    # dequant + activation-dtype GEMM
    wf = unpack_int4(packed, axis=-1).astype(jnp.float32)
    if w_scale.ndim == 3:
        N = wf.shape[-1]
        wf = (wf.reshape(K // g, g, N) * w_scale).reshape(K, N)
    else:
        wf = wf * w_scale
    return jnp.dot(x2, wf.astype(x2.dtype))


#: linear-weight leaf names eligible for serving-side packing (allowlist).
PACKABLE_NAMES = frozenset({
    "wq", "wk", "wv", "wo",                  # attention projections
    "w_in", "w_gate", "w_out",               # FFN / MoE experts
    "in_proj", "out_proj",                   # mamba
    "in_x", "in_g", "w_a", "w_x", "out",     # rg-lru
})


def pack_weight_nd(w: jnp.ndarray, cfg: QuantConfig):
    """Pack a [..., K, N] float weight, nibbles packed along N.  Works for
    plain [K,N], layer-stacked [L,K,N] and stacked experts [L,E,K,N].

    Scales follow `cfg.group_size`: 0 (or >= K) gives per-output-channel
    scales [..., 1, N]; a divisor G of K gives per-group scales
    [..., K//G, 1, N] — the same grouping the on-the-fly w4a16 backend
    computes, so grouped plans keep their numerics through a quantized
    checkpoint."""
    K, N = w.shape[-2], w.shape[-1]
    g = cfg.group_size
    if g and 0 < g < K:
        # same contract as the on-the-fly group_quantize: a group size that
        # doesn't divide K is a plan error, not a silent per-channel
        # fallback (the checkpoint must carry the numerics the plan names)
        assert K % g == 0, (K, g)
        wg = w.reshape(*w.shape[:-2], K // g, g, N)
        scale = quant_scale(wg, axis=-2, bits=cfg.w_bits)  # [..., K//g, 1, N]
        q = quantize(wg, scale, bits=cfg.w_bits).reshape(w.shape)
    else:
        scale = quant_scale(w, axis=-2, bits=cfg.w_bits)   # [..., 1, N]
        q = quantize(w, scale, bits=cfg.w_bits)
    return {"packed": pack_int4(q, axis=-1), "scale": scale}


def prepack_tree(params):
    """Add a `packed_km` planar K-major twin to every packed serving weight
    (see kernels/packing.py).  One-time, serving-side: the Pallas kernels
    then unpack with a shift/mask only — no per-step relayout.  No-op on
    unpacked leaves; safe to call on any plan_pack_tree output.

    MoE expert weights are skipped: they run through the batched einsum in
    models/moe.py, never the 2D kernels, so a twin would just double their
    footprint for the whole serving lifetime.

    Also commits the 16x256 per-nibble product tables to device
    (``packing.lut4_tables``), so a plan with ``lut4`` sites pays the LUT
    build at prepack time rather than inside the first serving step."""
    import jax

    from repro.kernels.packing import lut4_tables, nmajor_to_kmajor

    lut4_tables()

    def maybe(path, d):
        in_experts = any(
            str(getattr(p, "key", "")) == "experts" for p in path)
        if isinstance(d, dict) and "packed" in d and "packed_km" not in d \
                and not in_experts:
            # grouped scales [..., K//G, 1, N] need planar halves that cover
            # whole groups (row_mult = 2G); per-channel [..., 1, N] need 2.
            # Grouped is one rank deeper than the packed weight (holds for
            # plain [K, N/2] and layer-stacked [R, K, N/2] alike).
            rm = 2
            if d["scale"].ndim == d["packed"].ndim + 1:
                rm = 2 * (d["packed"].shape[-2] // d["scale"].shape[-3])
            return {**d, "packed_km": nmajor_to_kmajor(d["packed"], rm)}
        return d

    return jax.tree_util.tree_map_with_path(
        maybe, params, is_leaf=lambda n: isinstance(n, dict) and "packed" in n)


