"""Static timing analysis for LUT/CARRY4 netlists (paper Table III).

We cannot run Vivado in this environment, so critical-path delay is estimated
with a static timing model over the netlist graph using a 7-series-shaped
delay set.  The constants are calibrated ONCE against the paper's reported
breakdown for the proposed design (2.750 ns = 1.302 logic + 1.448 net,
Table III) and then held fixed for every design; the tests assert that the
paper's *orderings* (Proposed < LM < Acc) emerge from the model rather than
being hardcoded per-design.

Delay classes:
  * T_LUT       LUT input -> output (logic)
  * T_CYINIT    fabric CIN -> CO[0] through CYINIT mux (logic)
  * T_MUXCY     CO[i] -> CO[i+1] within a CARRY4 (logic)
  * T_CO_CIN    CO[3] -> next CARRY4 CIN over the dedicated link (logic)
  * T_XORCY     stage carry -> O[i] through XORCY (logic)
  * T_S_CO/T_S_O/T_DI_CO  S/DI pin -> CO/O of the same stage (logic)
  * T_NET_IN    primary input -> first cell (net)
  * T_NET       LUT output -> next cell input (net)
  * T_NET_SLICE LUT O6/O5 -> same-slice CARRY4 S/DI pin (dedicated, ~0)
  * T_NET_CO    CO[3] -> general fabric -> LUT input (net; the slow path the
                paper's chain-B trick avoids)
  * T_NET_OUT   final cell output -> product pin (net)
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

from .netlist import CONST0, CONST1, Carry4, Lut, Netlist


@dataclasses.dataclass(frozen=True)
class DelayModel:
    T_LUT: float = 0.124
    T_CYINIT: float = 0.510
    T_MUXCY: float = 0.117
    T_CO_CIN: float = 0.003
    T_XORCY: float = 0.314
    T_S_CO: float = 0.150
    T_S_O: float = 0.150
    T_DI_CO: float = 0.220
    T_NET_IN: float = 0.448
    T_NET: float = 0.350
    T_NET_SLICE: float = 0.020
    T_NET_CO: float = 0.820
    T_NET_OUT: float = 0.650


ARTIX7_CALIBRATED = DelayModel()


@dataclasses.dataclass
class Arrival:
    """Arrival time with its logic/net decomposition along the max path."""

    t: float = 0.0
    logic: float = 0.0
    net: float = 0.0

    def plus(self, logic: float = 0.0, net: float = 0.0) -> "Arrival":
        return Arrival(self.t + logic + net, self.logic + logic, self.net + net)


def _max_arr(*arrs: Arrival) -> Arrival:
    return max(arrs, key=lambda a: a.t)


def analyze(netlist: Netlist, model: DelayModel = ARTIX7_CALIBRATED) -> Dict[str, object]:
    """Return CPD (ns), its logic/net split, and per-output arrivals."""
    arr: Dict[str, Arrival] = {s: Arrival() for s in netlist.inputs}
    co_signals = set()

    def edge(sig: str, slice_local: bool = False) -> Arrival:
        """Arrival of `sig` at a consuming pin, including the routing edge."""
        if sig in (CONST0, CONST1):
            return Arrival()
        a = arr[sig]
        if sig in co_signals:
            return a.plus(net=model.T_NET_CO)      # CO -> fabric (slow)
        if slice_local:
            return a.plus(net=model.T_NET_SLICE)   # O6->S / O5->DI dedicated
        if a.t == 0.0 and sig in netlist.inputs:
            return a.plus(net=model.T_NET_IN)
        return a.plus(net=model.T_NET)

    for cell in netlist.cells:
        if isinstance(cell, Lut):
            ins = [s for s in cell.inputs if s not in (CONST0, CONST1)]
            worst = _max_arr(*(edge(s) for s in ins)) if ins else Arrival()
            out = worst.plus(logic=model.T_LUT)
            arr[cell.out_o6] = out
            if cell.is_dual:
                arr[cell.out_o5] = out
        elif isinstance(cell, Carry4):
            if cell.cin in (CONST0, CONST1):
                c = Arrival()
            elif cell.cin_dedicated:
                c = arr[cell.cin].plus(logic=model.T_CO_CIN)
            else:
                c = edge(cell.cin).plus(logic=model.T_CYINIT - model.T_MUXCY)
            for i in range(4):
                s_a = (edge(cell.s[i], slice_local=True)
                       if cell.s[i] not in (CONST0, CONST1) else Arrival())
                d_a = (edge(cell.di[i], slice_local=True)
                       if cell.di[i] not in (CONST0, CONST1) else Arrival())
                o_i = _max_arr(c.plus(logic=model.T_XORCY), s_a.plus(logic=model.T_S_O))
                c = _max_arr(
                    c.plus(logic=model.T_MUXCY),
                    s_a.plus(logic=model.T_S_CO),
                    d_a.plus(logic=model.T_DI_CO),
                )
                if cell.o_out[i] is not None:
                    arr[cell.o_out[i]] = o_i
                if cell.co_out[i] is not None:
                    arr[cell.co_out[i]] = c
                    co_signals.add(cell.co_out[i])
        else:
            raise TypeError(type(cell))

    outs = {s: arr[s].plus(net=model.T_NET_OUT) for s in netlist.outputs}
    crit_sig, crit = max(outs.items(), key=lambda kv: kv[1].t)
    return {
        "cpd": round(crit.t, 3),
        "logic": round(crit.logic, 3),
        "net": round(crit.net, 3),
        "critical_output": crit_sig,
        "arrivals": {k: round(v.t, 3) for k, v in outs.items()},
    }


def pipeline_stage_cpds(
    netlist: Netlist,
    register_after: Tuple[str, ...],
    model: DelayModel = ARTIX7_CALIBRATED,
    t_reg: float = 0.10,
) -> Dict[str, float]:
    """Two-stage pipelined CPD (paper §VI): registers after `register_after`.

    Stage 1 = inputs -> registered signals; stage 2 = registers -> outputs.
    Returns per-stage CPDs and the achievable Fmax.
    """
    full = analyze(netlist, model)
    arr: Dict[str, float] = {}
    # Stage 1: longest arrival among registered signals (re-run analyze and read)
    res = _arrivals_all(netlist, model)
    s1 = max(res[s].t for s in register_after) + t_reg
    # Stage 2: re-time with registered signals as fresh inputs (t=0).
    cut = set(register_after)
    res2 = _arrivals_all(netlist, model, zero_set=cut)
    s2 = max(res2[s].t for s in netlist.outputs) + model.T_NET_OUT + t_reg
    stage = max(s1, s2)
    return {
        "stage1_ns": round(s1, 3),
        "stage2_ns": round(s2, 3),
        "fmax_mhz": round(1e3 / stage, 1),
        "unpipelined_fmax_mhz": round(1e3 / full["cpd"], 1),
    }


def _arrivals_all(netlist, model, zero_set=frozenset()):
    """Full arrival map; signals in `zero_set` restart at t=0 (register cut)."""
    arr: Dict[str, Arrival] = {s: Arrival() for s in netlist.inputs}
    co_signals = set()

    def edge(sig, slice_local=False):
        if sig in (CONST0, CONST1):
            return Arrival()
        a = arr[sig]
        if sig in co_signals:
            return a.plus(net=model.T_NET_CO)
        if slice_local:
            return a.plus(net=model.T_NET_SLICE)
        if a.t == 0.0 and sig in netlist.inputs:
            return a.plus(net=model.T_NET_IN)
        return a.plus(net=model.T_NET)

    for cell in netlist.cells:
        if isinstance(cell, Lut):
            ins = [s for s in cell.inputs if s not in (CONST0, CONST1)]
            worst = _max_arr(*(edge(s) for s in ins)) if ins else Arrival()
            out = worst.plus(logic=model.T_LUT)
            for o in ([cell.out_o6] + ([cell.out_o5] if cell.is_dual else [])):
                arr[o] = Arrival() if o in zero_set else out
        elif isinstance(cell, Carry4):
            if cell.cin in (CONST0, CONST1):
                c = Arrival()
            elif cell.cin_dedicated:
                c = arr[cell.cin].plus(logic=model.T_CO_CIN)
            else:
                c = edge(cell.cin).plus(logic=model.T_CYINIT - model.T_MUXCY)
            for i in range(4):
                s_a = (edge(cell.s[i], slice_local=True)
                       if cell.s[i] not in (CONST0, CONST1) else Arrival())
                d_a = (edge(cell.di[i], slice_local=True)
                       if cell.di[i] not in (CONST0, CONST1) else Arrival())
                o_i = _max_arr(c.plus(logic=model.T_XORCY), s_a.plus(logic=model.T_S_O))
                c = _max_arr(
                    c.plus(logic=model.T_MUXCY),
                    s_a.plus(logic=model.T_S_CO),
                    d_a.plus(logic=model.T_DI_CO),
                )
                if cell.o_out[i] is not None:
                    arr[cell.o_out[i]] = Arrival() if cell.o_out[i] in zero_set else o_i
                if cell.co_out[i] is not None:
                    arr[cell.co_out[i]] = Arrival() if cell.co_out[i] in zero_set else c
                    co_signals.add(cell.co_out[i])
    return arr
