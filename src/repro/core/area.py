"""Resource accounting for multiplier netlists (paper Table II)."""

from __future__ import annotations

import math
from typing import Dict

from .netlist import Netlist


def resources(netlist: Netlist) -> Dict[str, int]:
    luts = netlist.lut_count()
    # alias LUTs (net renames, see mult4_baselines.build_acc_mult4) are free
    luts -= len(getattr(netlist, "alias_luts", ()))
    carry4 = netlist.carry4_count()
    # a 7-series slice holds 4 LUT6 + 1 CARRY4; slices is the binding resource
    slices = max(math.ceil(luts / 4), carry4)
    return {"luts": luts, "carry4": carry4, "slices_min": slices}
