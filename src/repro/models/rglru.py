"""Griffin/RecurrentGemma recurrent block: conv1d + RG-LRU gated linear
recurrence, computed with `lax.associative_scan` (log-depth, loop-free HLO).

Block layout follows Griffin (arXiv:2402.19427): two input branches
(linear->GeLU gate; linear->conv1d->RG-LRU), elementwise merge, output
projection.  Gate projections are full matrices (GEMM-heavy; quantized with
the paper's technique).  The recurrence itself runs in fp32.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, Runtime
from repro.core.qlinear import qdense
from repro.core.quant_plan import join_site
from repro.distributed.sharding import shard
from .common import normal_init
from .ssm import _causal_conv

_C = 8.0  # Griffin's fixed gate sharpness


def init_rglru(key, cfg: ArchConfig) -> Dict:
    D, W, K = cfg.d_model, cfg.lru_width or cfg.d_model, cfg.ssm_conv
    ks = jax.random.split(key, 6)
    return {
        "in_x": normal_init(ks[0], (D, W)),
        "in_g": normal_init(ks[1], (D, W)),
        "conv_w": normal_init(ks[2], (K, W), fan_in=K),
        "conv_b": jnp.zeros((W,)),
        "w_a": normal_init(ks[3], (W, W)),
        "b_a": jnp.zeros((W,)),
        "w_x": normal_init(ks[4], (W, W)),
        "b_x": jnp.zeros((W,)),
        # Lambda init so a^c in ~[0.9, 0.999] (Griffin appendix)
        "lam": jnp.log(jnp.expm1(
            -jnp.log(jnp.linspace(0.9, 0.999, W)) / _C
        )),
        "out": normal_init(ks[5], (W, D), fan_in=W),
    }


def init_rglru_cache(cfg: ArchConfig, batch: int) -> Dict:
    W = cfg.lru_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, W), jnp.float32),
        "h": jnp.zeros((batch, W), jnp.float32),
    }


def apply_rglru(
    params: Dict,
    x: jnp.ndarray,                   # [B, S, D]
    cfg: ArchConfig,
    rt: Runtime,
    cache: Optional[Dict] = None,
    update_cache: bool = False,
    site: str = "lru",
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    B, S, D = x.shape

    def qc(leaf):
        return rt.quant_cfg(cfg, join_site(site, leaf))

    g = jax.nn.gelu(qdense(params["in_g"], x, qc("in_g"),
                           tag=join_site(site, "in_g")))
    u = qdense(params["in_x"], x, qc("in_x"), tag=join_site(site, "in_x"))
    u = shard(u, "act_btf")
    conv_state = cache["conv"] if cache is not None else None
    u, new_conv = _causal_conv(u, params["conv_w"], params["conv_b"], conv_state)

    r = jax.nn.sigmoid(qdense(params["w_a"], u, qc("w_a"), params["b_a"],
                              tag=join_site(site, "w_a"))).astype(jnp.float32)
    i = jax.nn.sigmoid(qdense(params["w_x"], u, qc("w_x"), params["b_x"],
                              tag=join_site(site, "w_x"))).astype(jnp.float32)

    log_a = -_C * jax.nn.softplus(params["lam"]) * r            # [B,S,W] <= 0
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    gated = beta * i * u.astype(jnp.float32)

    if cache is not None and S == 1:
        h = a[:, 0] * cache["h"] + gated[:, 0]                  # [B, W]
        hs = h[:, None]
        new_cache = {"conv": new_conv, "h": h}
    else:
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, b1 * a2 + b2

        h0 = cache["h"] if cache is not None else jnp.zeros((B, u.shape[-1]), jnp.float32)
        # inject initial state into the first step's additive term
        gated = gated.at[:, 0].add(a[:, 0] * h0)
        _, hs = jax.lax.associative_scan(combine, (a, gated), axis=1)
        new_cache = {"conv": new_conv, "h": hs[:, -1]} if update_cache else None

    y = hs.astype(x.dtype) * g
    out = qdense(params["out"], y, qc("out"), tag=join_site(site, "out"))
    return shard(out, "act_btd"), new_cache
