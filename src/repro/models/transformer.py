"""Decoder LM assembly: embedding -> (scan over pattern-repeat groups of
blocks) -> tail blocks -> norm -> logits, with unified KV/state caches and
chunked cross-entropy.

Layer stacking: `cfg.pattern` defines one repeat unit (e.g. ("A",) uniform,
("M",) mamba, ("R","R","A") recurrentgemma); params/caches for the
`cfg.n_repeats` units are stacked on a leading axis and iterated with
`lax.scan` (production/memory variant) or a Python loop (`scan_layers=False`
cost-probe variant — exact HLO FLOP accounting, see EXPERIMENTS.md).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, Runtime
from repro.core.qlinear import qdense
from repro.core.quant_plan import (
    active_plan,
    join_site,
    layers_per_repeat,
    plan_repeat_uniform,
)
from repro.distributed.sharding import shard
from .attention import apply_attention, init_attention, init_attn_cache
from .common import normal_init, rms_norm, sinusoidal_pos_embed
from .ffn import apply_ffn, init_ffn
from .moe import apply_moe, init_moe
from .rglru import apply_rglru, init_rglru, init_rglru_cache
from .ssm import apply_mamba, init_mamba, init_mamba_cache


# ----------------------------------------------------------------- blocks --
def init_block(key, block_type: str, cfg: ArchConfig) -> Dict:
    ks = jax.random.split(key, 4)
    D = cfg.d_model
    p: Dict = {"norm1": jnp.ones((D,))}
    if block_type == "A":
        p["attn"] = init_attention(ks[0], cfg)
        if cfg.family == "moe":
            p["norm2"] = jnp.ones((D,))
            p["moe"] = init_moe(ks[1], cfg)
            if cfg.shared_expert:
                p["shared"] = init_ffn(ks[2], cfg, cfg.d_ff_expert or cfg.d_ff)
            if cfg.moe_dense_ff:
                p["dense_ffn"] = init_ffn(ks[3], cfg, cfg.moe_dense_ff)
        elif cfg.d_ff:
            p["norm2"] = jnp.ones((D,))
            p["ffn"] = init_ffn(ks[1], cfg)
    elif block_type == "M":
        p["mamba"] = init_mamba(ks[0], cfg)
    elif block_type == "R":
        p["lru"] = init_rglru(ks[0], cfg)
        if cfg.d_ff:
            p["norm2"] = jnp.ones((D,))
            p["ffn"] = init_ffn(ks[1], cfg)
    else:
        raise ValueError(block_type)
    return p


def init_block_cache(block_type: str, cfg: ArchConfig, rt: Runtime,
                     batch: int, seq: int):
    if block_type == "A":
        return {"attn": init_attn_cache(cfg, rt, batch, seq)}
    if block_type == "M":
        return {"mamba": init_mamba_cache(cfg, batch)}
    if block_type == "R":
        return {"lru": init_rglru_cache(cfg, batch)}
    raise ValueError(block_type)


def apply_block(
    block_type: str, p: Dict, x, cfg, rt, positions,
    cache=None, update_cache=False, site: str = "",
):
    """Returns (x, new_cache, aux).  `site` is the block's site prefix
    (e.g. "block[3]"): sub-layers resolve their quant backend and autotune
    tiles under it (see core.quant_plan)."""
    aux = jnp.zeros((), jnp.float32)
    normed = rms_norm(x, p["norm1"], cfg.norm_eps)
    if block_type == "A":
        h, nc = apply_attention(
            p["attn"], normed, cfg, rt, positions,
            cache.get("attn") if cache else None, update_cache, site=site,
        )
        x = x + h
        if cfg.family == "moe":
            n2 = rms_norm(x, p["norm2"], cfg.norm_eps)
            my, aux = apply_moe(p["moe"], n2, cfg, rt,
                                site=join_site(site, "moe"))
            extra = 0.0
            if cfg.shared_expert:
                extra = apply_ffn(p["shared"], n2, cfg, rt,
                                  site=join_site(site, "shared"))
            if cfg.moe_dense_ff:
                extra = apply_ffn(p["dense_ffn"], n2, cfg, rt,
                                  site=join_site(site, "dense_ffn"))
            x = x + my + extra
        elif cfg.d_ff:
            x = x + apply_ffn(p["ffn"], rms_norm(x, p["norm2"], cfg.norm_eps),
                              cfg, rt, site=join_site(site, "ffn"))
        return x, ({"attn": nc} if nc is not None else None), aux
    if block_type == "M":
        h, nc = apply_mamba(p["mamba"], normed, cfg, rt,
                            cache.get("mamba") if cache else None, update_cache,
                            site=join_site(site, "mamba"))
        return x + h, ({"mamba": nc} if nc is not None else None), aux
    if block_type == "R":
        h, nc = apply_rglru(p["lru"], normed, cfg, rt,
                            cache.get("lru") if cache else None, update_cache,
                            site=join_site(site, "lru"))
        x = x + h
        if cfg.d_ff:
            x = x + apply_ffn(p["ffn"], rms_norm(x, p["norm2"], cfg.norm_eps),
                              cfg, rt, site=join_site(site, "ffn"))
        return x, ({"lru": nc} if nc is not None else None), aux
    raise ValueError(block_type)


# ------------------------------------------------------------------ model --
def init_model(key, cfg: ArchConfig) -> Dict:
    ks = jax.random.split(key, 4 + len(cfg.tail))
    Vp, D = cfg.vocab_padded, cfg.d_model
    params: Dict = {
        "embed": {"tok": normal_init(ks[0], (Vp, D), fan_in=D)},
        "final_norm": jnp.ones((D,)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": normal_init(ks[1], (D, Vp))}

    def init_unit(k):
        uks = jax.random.split(k, len(cfg.pattern))
        return {f"u{j}": init_block(uks[j], bt, cfg)
                for j, bt in enumerate(cfg.pattern)}

    unit_keys = jax.random.split(ks[2], cfg.n_repeats)
    params["layers"] = jax.vmap(init_unit)(unit_keys)   # stacked on axis 0
    for t, bt in enumerate(cfg.tail):
        params[f"tail{t}"] = init_block(ks[3 + t], bt, cfg)
    return params


def init_caches(cfg: ArchConfig, rt: Runtime, batch: int, seq: int):
    def unit_cache(_):
        return {f"u{j}": init_block_cache(bt, cfg, rt, batch, seq)
                for j, bt in enumerate(cfg.pattern)}

    stacked = jax.vmap(unit_cache)(jnp.arange(cfg.n_repeats))
    tail = {f"tail{t}": init_block_cache(bt, cfg, rt, batch, seq)
            for t, bt in enumerate(cfg.tail)}
    return {"rep": stacked, "tail": tail}


def forward(
    params: Dict,
    tokens: jnp.ndarray,              # [B, S] int32
    cfg: ArchConfig,
    rt: Runtime,
    positions: Optional[jnp.ndarray] = None,   # [B,S] or [3,B,S]
    caches: Optional[Dict] = None,
    update_cache: bool = False,
    return_hidden: bool = False,
):
    """Returns (logits_or_hidden, new_caches, aux_mean)."""
    B, S = tokens.shape
    tokens = shard(tokens, "tokens")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    dt = jnp.bfloat16 if rt.compute_dtype == "bfloat16" else jnp.float32

    x = params["embed"]["tok"][tokens].astype(dt)
    if cfg.rope == "none":
        tpos = positions if positions.ndim == 2 else positions[0]
        x = x + sinusoidal_pos_embed(tpos, cfg.d_model).astype(dt)
    x = shard(x, "act_btd")

    P = len(cfg.pattern)

    def make_body(unit_sites):
        def unit_body(carry, xs):
            xc, aux_acc = carry
            unit_params, unit_cache = xs
            new_unit_cache = {} if unit_cache is not None else None
            for j, bt in enumerate(cfg.pattern):
                blk_cache = (unit_cache[f"u{j}"]
                             if unit_cache is not None else None)
                xc, nc, aux = apply_block(
                    bt, unit_params[f"u{j}"], xc, cfg, rt, positions,
                    blk_cache, update_cache, site=unit_sites[j],
                )
                if new_unit_cache is not None:
                    new_unit_cache[f"u{j}"] = nc if nc is not None else blk_cache
            return (xc, aux_acc + aux), new_unit_cache

        if rt.remat == "dots":
            return jax.checkpoint(
                unit_body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            )
        if rt.remat == "full":
            return jax.checkpoint(unit_body)
        return unit_body

    aux0 = jnp.zeros((), jnp.float32)
    rep_caches = caches["rep"] if caches is not None else None
    # per-site plan resolution happens OUTSIDE the scan body (at trace
    # time), so the compiled graph stays static: lax.scan traces one body
    # for all repeat units and therefore requires every unit to resolve to
    # the same per-site configs.  A plan that distinguishes repeats (e.g.
    # "block[0].*=float") — or a plan-packed tree split per repeat — takes
    # the unrolled layer loop instead.
    per_repeat = layers_per_repeat(params)
    use_scan = (rt.scan_layers and not per_repeat
                and plan_repeat_uniform(active_plan(cfg, rt), cfg))
    if use_scan:
        body = make_body([f"block[{j}]" for j in range(P)])
        if rep_caches is None:
            (x, aux_sum), new_rep = jax.lax.scan(
                lambda c, p: body(c, (p, None)), (x, aux0), params["layers"]
            )
        else:
            (x, aux_sum), new_rep = jax.lax.scan(
                body, (x, aux0), (params["layers"], rep_caches)
            )
    else:
        new_rep_list = []
        carry = (x, aux0)
        for r in range(cfg.n_repeats):
            unit_p = (params["layers"][f"r{r}"] if per_repeat
                      else jax.tree.map(lambda a: a[r], params["layers"]))
            unit_c = (jax.tree.map(lambda a: a[r], rep_caches)
                      if rep_caches is not None else None)
            body = make_body([f"block[{r * P + j}]" for j in range(P)])
            carry, nc = body(carry, (unit_p, unit_c))
            new_rep_list.append(nc)
        x, aux_sum = carry
        new_rep = (jax.tree.map(lambda *xs: jnp.stack(xs), *new_rep_list)
                   if rep_caches is not None else None)

    new_caches = None
    if caches is not None:
        new_caches = {"rep": new_rep, "tail": {}}
    for t, bt in enumerate(cfg.tail):
        tc = caches["tail"][f"tail{t}"] if caches is not None else None
        x, nc, aux = apply_block(bt, params[f"tail{t}"], x, cfg, rt,
                                 positions, tc, update_cache,
                                 site=f"block[{cfg.n_repeats * P + t}]")
        aux_sum = aux_sum + aux
        if new_caches is not None:
            new_caches["tail"][f"tail{t}"] = nc if nc is not None else tc

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    aux_mean = aux_sum / max(cfg.n_layers, 1)
    if return_hidden:
        return x, new_caches, aux_mean

    logits = _logits(params, x, cfg, rt)
    return logits, new_caches, aux_mean


def _logits(params, x, cfg: ArchConfig, rt: Runtime):
    """x [..., D] -> logits [..., Vp]; keeps token dims data-sharded and the
    vocab dim TP-sharded (2D flattened-token and 3D [B,S,D] forms).

    The head quantizes per the plan's "lm_head" site (uniform legacy
    configs map quantize_embedding=False to a float lm_head rule)."""
    if cfg.tie_embeddings:
        w = params["embed"]["tok"].astype(x.dtype)              # [Vp, D]
        logits = jnp.einsum("...d,vd->...v", x, w)
    else:
        logits = qdense(params["lm_head"]["w"], x,
                        rt.quant_cfg(cfg, "lm_head"), tag="lm_head")
    return shard(logits, "act_tv" if logits.ndim == 2 else "act_btv")


# ------------------------------------------------------------------- loss --
def lm_loss(
    params: Dict,
    tokens: jnp.ndarray,              # [B, S+1]: inputs/targets shifted
    cfg: ArchConfig,
    rt: Runtime,
    positions: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Dict]:
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    hidden, _, aux = forward(params, inp, cfg, rt, positions,
                             return_hidden=True)
    B, S, D = hidden.shape
    hf = hidden.reshape(B * S, D)
    tf = tgt.reshape(B * S)

    chunk = rt.loss_chunk
    if chunk and (B * S) % chunk == 0 and (B * S) > chunk:
        n = (B * S) // chunk

        def step(acc, xs):
            h, t = xs
            nll = _xent(params, h, t, cfg, rt)
            return acc + jnp.sum(nll), None

        total, _ = jax.lax.scan(
            step, jnp.zeros((), jnp.float32),
            (hf.reshape(n, chunk, D), tf.reshape(n, chunk)),
        )
    else:
        total = jnp.sum(_xent(params, hf, tf, cfg, rt))

    loss = total / (B * S)
    if cfg.n_experts:
        loss = loss + cfg.router_aux_coef * aux
    return loss, {"nll": total / (B * S), "aux": aux}


def _xent(params, h, t, cfg: ArchConfig, rt: Runtime):
    h = shard(h, "act_td")                                      # [n, D]
    logits = _logits(params, h, cfg, rt)                        # [n, Vp]
    logits = logits.astype(jnp.float32)
    Vp = logits.shape[-1]
    if Vp != cfg.vocab:
        mask = jnp.arange(Vp) < cfg.vocab
        logits = jnp.where(mask[None, :], logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, t[:, None], axis=-1)[:, 0]
    return lse - picked


# ------------------------------------------------------------ serve steps --
def prefill(params, tokens, cfg, rt, caches, positions=None):
    """Fill caches with a prompt; returns (last_logits [B, V], caches)."""
    hidden, new_caches, _ = forward(
        params, tokens, cfg, rt, positions, caches,
        update_cache=True, return_hidden=True,
    )
    logits = _logits(params, hidden[:, -1:], cfg, rt)[:, 0]
    return logits, new_caches


def decode_step(params, token, cfg, rt, caches, positions):
    """One decode step. token [B, 1]; positions [B, 1] absolute positions."""
    hidden, new_caches, _ = forward(
        params, token, cfg, rt, positions, caches,
        update_cache=True, return_hidden=True,
    )
    logits = _logits(params, hidden, cfg, rt)[:, 0]
    return logits, new_caches
