"""GQA attention with RoPE/M-RoPE, qk-norm, sliding windows, quantizable
KV cache, and two interchangeable implementations:

  * ``full``    -- materialized scores (cost-probe variant; exact HLO FLOPs)
  * ``chunked`` -- lax.map over query chunks against the full K/V (memory-
                   bounded for 32k prefill; production variant)

All projections route through the paper's QuantizedLinear (`qdense`).

KV caches are ring buffers of `min(seq, window)` slots carrying an absolute-
position tensor `kpos` [B, size] (-1 = empty), which makes causal/window/
validity masking uniform across full and sliding-window caches and across
prefill/decode.  `cache_dtype="int8"` stores quantized K/V with per-token
scales (a §Perf memory-term lever: ~2x less decode traffic than bf16).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, Runtime
from repro.core.qlinear import qdense
from repro.core.quant_plan import join_site
from repro.distributed.sharding import dp_axes, shard, shard_spec, tp_size
from .common import apply_mrope, apply_rope, normal_init, rms_norm

NEG_INF = -1e30


def _attn_strategy(n_units: int, seq: int) -> str:
    """How to use the TP axis inside the attention core:
      head -- units divide TP: classic Megatron head sharding;
      seq  -- units don't divide but the (chunk) sequence does: shard query
              positions on TP, replicate K/V (context parallelism; the k/v
              replication traffic is tiny vs replicating score FLOPs 16x);
      none -- decode / tiny shapes: replicate heads.
    Never let GSPMD partial-shard `hd` — that turns the attention backward
    into giant score all-reduces (measured in EXPERIMENTS.md §Perf)."""
    tp = tp_size()
    if tp <= 1:
        return "none"
    if n_units % tp == 0:
        return "head"
    if seq > 1 and seq % tp == 0:
        return "seq"
    return "none"


def _constrain(t: jnp.ndarray, strategy: str, batch_sharded: bool,
               *, unit_axis: int = 2, seq_axis: int = 1,
               kv_in_seq: bool = False):
    if tp_size() <= 1:
        return t
    dpa = dp_axes()
    dspec = (dpa if len(dpa) > 1 else (dpa[0] if dpa else None)) \
        if batch_sharded else None
    ax = [None] * t.ndim
    ax[0] = dspec
    if strategy == "head":
        ax[unit_axis] = "model"
    elif strategy == "seq" and not kv_in_seq:
        ax[seq_axis] = "model"
    # strategy none / kv under seq-sharding: replicated over model
    return shard_spec(t, P(*ax))


def init_attention(key, cfg: ArchConfig) -> Dict:
    hd, H, KV, D = cfg.hd, cfg.n_heads, cfg.n_kv_heads, cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": normal_init(ks[0], (D, H * hd)),
        "wk": normal_init(ks[1], (D, KV * hd)),
        "wv": normal_init(ks[2], (D, KV * hd)),
        "wo": normal_init(ks[3], (H * hd, D), fan_in=H * hd),
    }
    if cfg.qkv_bias:
        p["wq_bias"] = jnp.zeros((H * hd,))
        p["wk_bias"] = jnp.zeros((KV * hd,))
        p["wv_bias"] = jnp.zeros((KV * hd,))
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,))
        p["k_norm"] = jnp.ones((hd,))
    return p


# ----------------------------------------------------------- KV cache ------
def init_attn_cache(cfg: ArchConfig, rt: Runtime, batch: int, seq: int) -> Dict:
    """Cache for one attention layer. `seq` = max context length."""
    size = min(seq, cfg.local_window) if cfg.local_window else seq
    kv, hd = cfg.n_kv_heads, cfg.hd
    cache = {
        "pos": jnp.zeros((batch,), jnp.int32),
        "kpos": jnp.full((batch, size), -1, jnp.int32),
    }
    if rt.cache_dtype == "int8":
        z = jnp.zeros((batch, size, kv, hd), jnp.int8)
        s = jnp.zeros((batch, size, kv, 1), jnp.float32)
        cache.update({"k": z, "v": z, "k_scale": s, "v_scale": s})
    elif rt.cache_dtype == "int4":
        # the paper's 4-bit format applied to the KV cache: packed nibble
        # pairs + per-(token, head) scales — 4x fewer cache bytes than bf16
        z = jnp.zeros((batch, size, kv, hd // 2), jnp.uint8)
        s = jnp.zeros((batch, size, kv, 1), jnp.float32)
        cache.update({"k": z, "v": z, "k_scale": s, "v_scale": s})
    else:
        dt = jnp.bfloat16 if rt.cache_dtype == "bfloat16" else jnp.float32
        z = jnp.zeros((batch, size, kv, hd), dt)
        cache.update({"k": z, "v": z})
    return cache


def quantize_kv(val, int4: bool):
    """Per-(token, head) absmax quantization of K/V slabs [..., hd].
    Shared by the contiguous ring cache and the paged pool (kv_pages) so the
    two layouts stay bit-identical."""
    qmax = 7.0 if int4 else 127.0
    scale = jnp.max(jnp.abs(val), axis=-1, keepdims=True) / qmax + 1e-8
    q = jnp.clip(jnp.round(val / scale), -qmax, qmax).astype(jnp.int8)
    if int4:
        from repro.core.quant import pack_int4

        q = pack_int4(q, axis=-1)
    return q, scale.astype(jnp.float32)


def dequantize_kv(q, scale):
    """Inverse of quantize_kv (uint8 => packed nibbles)."""
    if q.dtype == jnp.uint8:
        from repro.core.quant import unpack_int4

        q = unpack_int4(q, axis=-1)
    return (q.astype(jnp.float32) * scale).astype(jnp.bfloat16)


def _scatter_time(buf, val, slots):
    """buf [B, size, ...] <- val [B, n, ...] at slot indices slots [B, n].
    Out-of-range slots (the drop sentinel for pad/invalid positions) are
    silently discarded."""
    bidx = jnp.arange(buf.shape[0])[:, None] * jnp.ones_like(slots)
    return buf.at[bidx, slots].set(val.astype(buf.dtype), mode="drop")


def _dus_time(buf, val, start):
    """buf [B, size, ...] <- val [B, n, ...] at contiguous slots from scalar
    `start`.  dynamic-update-slice instead of scatter: 5x cheaper in the XLA
    cost model and genuinely faster on TPU (no index vector materialized)."""
    idx = (0, start) + (0,) * (buf.ndim - 2)
    return jax.lax.dynamic_update_slice(buf, val.astype(buf.dtype), idx)


def _cache_write(cache: Dict, k, v, abs_pos, aligned: bool = False) -> Dict:
    """Write k/v [B, n, KV, hd] whose absolute positions are abs_pos [B, n].

    `aligned=True` asserts every batch row writes the same positions
    (step-aligned serving): contiguous DUS writes (positions must not wrap
    mid-range — callers pass n=1 or a non-wrapping prefill range).  In the
    scatter path, negative positions (left-pad / inactive serving rows) are
    routed out of bounds and dropped.
    """
    size = cache["k"].shape[1]
    slots = jnp.where(abs_pos >= 0, abs_pos % size, size)   # size => dropped
    out = dict(cache)
    write = ((lambda buf, val: _dus_time(buf, val, slots[0, 0]))
             if aligned else (lambda buf, val: _scatter_time(buf, val, slots)))
    if "k_scale" in cache:
        int4 = cache["k"].dtype == jnp.uint8        # packed-nibble cache
        for name, val in (("k", k), ("v", v)):
            q, scale = quantize_kv(val, int4)
            out[name] = write(cache[name], q)
            out[name + "_scale"] = write(cache[name + "_scale"], scale)
    else:
        out["k"] = write(cache["k"], k)
        out["v"] = write(cache["v"], v)
    out["kpos"] = write(cache["kpos"], abs_pos)
    return out


def _cache_read(cache: Dict) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if "k_scale" in cache:
        return (dequantize_kv(cache["k"], cache["k_scale"]),
                dequantize_kv(cache["v"], cache["v_scale"]))
    return cache["k"], cache["v"]


# ------------------------------------------------------------ core ---------
def _gqa_block(q, k, v, mask, batch_sharded=True):
    """q [B,n,KV,G,hd]; k/v [B,Skv,KV,hd]; mask [B,n,Skv] bool."""
    strategy = _attn_strategy(k.shape[2], q.shape[1])
    q = _constrain(q, strategy, batch_sharded)
    k = _constrain(k, strategy, batch_sharded, kv_in_seq=True)
    v = _constrain(v, strategy, batch_sharded, kv_in_seq=True)
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bqkgh,btkh->bkgqt", q, k).astype(jnp.float32) * scale
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqt,btkh->bqkgh", probs.astype(v.dtype), v)
    return _constrain(out, strategy, batch_sharded)


def attention_core(
    q: jnp.ndarray,                 # [B, Sq, H, hd]
    k: jnp.ndarray,                 # [B, Skv, KV, hd]
    v: jnp.ndarray,
    *,
    q_positions: jnp.ndarray,       # [B, Sq]
    k_positions: jnp.ndarray,       # [B, Skv]
    window: int,
    impl: str,
    chunk_q: int,
    tag: str = "",
) -> jnp.ndarray:
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    tp = tp_size()
    batch_sharded = B > 1
    if impl == "flash" and tp <= 1:
        # tiled online-softmax prefill (kernels.paged_attention): scores
        # only ever exist as [bq, bk] tiles.  TP runs keep the sharded
        # chunked path — the flash kernel carries no partition constraints.
        from repro.kernels import ops

        return ops.flash_prefill(q, k, v, q_positions, k_positions,
                                 window=window, tag=tag)
    if impl == "flash":
        impl = "chunked"
    if tp > 1 and KV % tp != 0 and H % tp == 0:
        # Megatron-style KV-head duplication: q-heads shard on TP, each
        # shard holds copies of the KV heads it needs (no cross-shard math).
        k = jnp.repeat(k, H // KV, axis=2)
        v = jnp.repeat(v, H // KV, axis=2)
        KV = H
    qg = q.reshape(B, Sq, KV, H // KV, hd)

    def mask3(qpos):                         # [B, n, Skv]
        m = (qpos[:, :, None] >= k_positions[:, None, :]) \
            & (k_positions[:, None, :] >= 0)
        if window:
            m &= (qpos[:, :, None] - k_positions[:, None, :]) < window
        return m

    if impl == "full" or Sq <= chunk_q:
        out = _gqa_block(qg, k, v, mask3(q_positions), batch_sharded)
        return out.reshape(B, Sq, H, hd)

    nq = -(-Sq // chunk_q)
    pad = nq * chunk_q - Sq
    qg_p = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    qpos_p = jnp.pad(q_positions, ((0, 0), (0, pad)), constant_values=-1)
    qg_b = qg_p.reshape(B, nq, chunk_q, KV, H // KV, hd).swapaxes(0, 1)
    qpos_b = qpos_p.reshape(B, nq, chunk_q).swapaxes(0, 1)

    out = jax.lax.map(
        lambda args: _gqa_block(args[0], k, v, mask3(args[1]), batch_sharded),
        (qg_b, qpos_b)
    )
    out = out.swapaxes(0, 1).reshape(B, nq * chunk_q, KV, H // KV, hd)[:, :Sq]
    return out.reshape(B, Sq, H, hd)


# ------------------------------------------------------------ module -------
def apply_attention(
    params: Dict,
    x: jnp.ndarray,                  # [B, S, D]
    cfg: ArchConfig,
    rt: Runtime,
    positions: jnp.ndarray,          # [B, S] (or [3, B, S] for mrope)
    cache: Optional[Dict] = None,
    update_cache: bool = False,
    site: str = "",
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd

    # one site string keys both the plan's backend choice and per-call-site
    # tile tuning in kernels.autotune (QKV share a GEMM shape per config so
    # they share a site; wo differs)
    qkv_site = join_site(site, "attn.qkv")
    wo_site = join_site(site, "attn.wo")
    qc = rt.quant_cfg(cfg, qkv_site)
    q = qdense(params["wq"], x, qc, params.get("wq_bias"), tag=qkv_site)
    k = qdense(params["wk"], x, qc, params.get("wk_bias"), tag=qkv_site)
    v = qdense(params["wv"], x, qc, params.get("wv_bias"), tag=qkv_site)
    q = shard(q, "act_bthd")
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)

    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)

    tpos = positions if positions.ndim == 2 else positions[0]  # temporal
    if cfg.rope == "rope":
        q = apply_rope(q, tpos, cfg.rope_theta)
        k = apply_rope(k, tpos, cfg.rope_theta)
    elif cfg.rope == "mrope":
        mp = positions if positions.ndim == 3 else jnp.stack([tpos] * 3)
        q = apply_mrope(q, mp, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, mp, cfg.rope_theta, cfg.mrope_sections)

    new_cache = None
    if cache is not None and "slots" in cache:
        # ---- ragged token-major step: B == 1, S == packed token rows ------
        # Every row (prefill-chunk slice or decode token) routes through the
        # block-table row cache["slots"] names.  Write-then-attend makes one
        # mask rule exact for both: the chunk's K/V lands in the pool first,
        # so pos <= token_pos is causal for prefill rows and last-token for
        # decode rows (see kernels.ragged_attention).
        from repro.kernels import ops
        from repro.serving.kv_pages import ragged_paged_write

        new_cache = ragged_paged_write(cache, k, v, tpos)
        out = ops.ragged_paged_attention(
            q[0], new_cache["k"], new_cache["v"], new_cache["tbl"],
            cache["slots"], tpos[0],
            new_cache.get("k_scale"), new_cache.get("v_scale"),
            window=cfg.local_window,
            tag=join_site(site, "attn.ragged"),
        )[None]
    elif cache is not None and "tbl" in cache:
        # ---- paged KV (serving): pool + block table, see serving/kv_pages --
        from repro.serving.kv_pages import paged_read, paged_write

        if S == 1:
            new_cache = paged_write(cache, k, v, tpos)
            if rt.paged_attn == "fused" and tp_size() <= 1:
                # decode: consume the pages where they live — the fused
                # kernel walks the block table with online-softmax
                # accumulation; no paged_read, no dense KV materialization
                from repro.kernels import ops

                out = ops.paged_decode_attention(
                    q[:, 0], new_cache["k"], new_cache["v"],
                    new_cache["tbl"], tpos[:, -1],
                    new_cache.get("k_scale"), new_cache.get("v_scale"),
                    window=cfg.local_window,
                    tag=join_site(site, "attn.paged_decode"),
                )[:, None]
            else:
                # gather baseline (and TP fallback): reconstruct the dense
                # layout, attend over it — the bit-exactness reference
                kf, vf, kpos = paged_read(new_cache, tpos[:, -1])
                out = attention_core(
                    q, kf, vf,
                    q_positions=tpos, k_positions=kpos,
                    window=cfg.local_window, impl="full",
                    chunk_q=rt.attn_chunk_q,
                )
        elif rt.prefill_over_cache:
            # tail prefill (prefix-cache hit): the query covers only the
            # uncached suffix; its keys join the prefix K/V already living
            # in shared pages, so write the suffix first and attend over
            # the gathered pool — the same dense layout the decode gather
            # baseline reconstructs, with kpos masking the empty slots.
            new_cache = paged_write(cache, k, v, tpos) if update_cache \
                else cache
            kf, vf, kpos = paged_read(new_cache, tpos[:, -1])
            out = attention_core(
                q, kf, vf,
                q_positions=tpos, k_positions=kpos,
                window=cfg.local_window, impl=rt.attn_impl,
                chunk_q=rt.attn_chunk_q, tag=join_site(site, "attn.prefill"),
            )
        else:
            # prefill: the prompt is the whole context — attend in-flight,
            # write it into the pages for later decode steps
            out = attention_core(
                q, k, v,
                q_positions=tpos, k_positions=tpos,
                window=cfg.local_window, impl=rt.attn_impl,
                chunk_q=rt.attn_chunk_q, tag=join_site(site, "attn.prefill"),
            )
            if update_cache:
                new_cache = paged_write(cache, k, v, tpos)
    elif cache is not None and S == 1:
        # ---- decode: append one token, attend over the cache --------------
        new_cache = _cache_write(cache, k, v, tpos, aligned=rt.aligned_decode)
        new_cache["pos"] = cache["pos"] + 1
        kf, vf = _cache_read(new_cache)
        out = attention_core(
            q, kf, vf,
            q_positions=tpos, k_positions=new_cache["kpos"],
            window=cfg.local_window, impl="full", chunk_q=rt.attn_chunk_q,
        )
    else:
        # ---- train / prefill ----------------------------------------------
        out = attention_core(
            q, k, v,
            q_positions=tpos, k_positions=tpos,
            window=cfg.local_window, impl=rt.attn_impl, chunk_q=rt.attn_chunk_q,
            tag=join_site(site, "attn.prefill"),
        )
        if update_cache and cache is not None:
            size = cache["k"].shape[1]
            take = min(S, size)
            # prefill fills a contiguous, non-wrapping range: DUS-safe when
            # batch-aligned (ring wrap only matters once pos > size, i.e.
            # decode, which writes single slots)
            wpos = tpos[:, -take:]
            if not rt.aligned_decode:
                # chunked prefill can re-present already-cached positions
                # (a resume landing mid-way through a partial page): write
                # only the uncovered suffix — covered slots are routed to
                # the drop sentinel instead of re-scattered.  The aligned
                # path keeps its single DUS (a -1 would skew its start slot
                # and clamp the write onto the ring tail).
                wpos = jnp.where(wpos >= cache["pos"][:, None], wpos, -1)
            new_cache = _cache_write(
                cache, k[:, -take:], v[:, -take:], wpos,
                aligned=rt.aligned_decode,
            )
            new_cache["pos"] = cache["pos"] + S

    out = out.reshape(B, S, H * hd)
    y = qdense(params["wo"], out, rt.quant_cfg(cfg, wo_site), tag=wo_site)
    return shard(y, "act_btd"), new_cache
