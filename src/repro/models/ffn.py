"""Dense FFN blocks: SwiGLU (llama-family) and GELU (starcoder2/musicgen),
all projections through the paper's quantized linear."""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, Runtime
from repro.core.qlinear import qdense
from repro.core.quant_plan import join_site
from repro.distributed.sharding import shard
from .common import normal_init


def init_ffn(key, cfg: ArchConfig, d_ff: int = 0) -> Dict:
    D, F = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w_in": normal_init(ks[0], (D, F)),
         "w_out": normal_init(ks[1], (F, D), fan_in=F)}
    if cfg.ffn_type == "swiglu":
        p["w_gate"] = normal_init(ks[2], (D, F))
    if cfg.mlp_bias:
        p["b_in"] = jnp.zeros((F,))
        p["b_out"] = jnp.zeros((D,))
    return p


def apply_ffn(params: Dict, x: jnp.ndarray, cfg: ArchConfig, rt: Runtime,
              site: str = "ffn") -> jnp.ndarray:
    # sites key the plan's per-site backend choice AND per-call-site tile
    # tuning in kernels.autotune: the up/down projections are the serving
    # hot path and tune independently
    s_in, s_gate, s_out = (join_site(site, "w_in"), join_site(site, "w_gate"),
                           join_site(site, "w_out"))
    h = qdense(params["w_in"], x, rt.quant_cfg(cfg, s_in),
               params.get("b_in"), tag=s_in)
    if cfg.ffn_type == "swiglu":
        g = qdense(params["w_gate"], x, rt.quant_cfg(cfg, s_gate), tag=s_gate)
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    h = shard(h, "act_btf")
    y = qdense(params["w_out"], h, rt.quant_cfg(cfg, s_out),
               params.get("b_out"), tag=s_out)
    return shard(y, "act_btd")
