"""Dense FFN blocks: SwiGLU (llama-family) and GELU (starcoder2/musicgen),
all projections through the paper's quantized linear."""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, Runtime
from repro.core.qlinear import qdense
from repro.distributed.sharding import shard
from .common import normal_init


def init_ffn(key, cfg: ArchConfig, d_ff: int = 0) -> Dict:
    D, F = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w_in": normal_init(ks[0], (D, F)),
         "w_out": normal_init(ks[1], (F, D), fan_in=F)}
    if cfg.ffn_type == "swiglu":
        p["w_gate"] = normal_init(ks[2], (D, F))
    if cfg.mlp_bias:
        p["b_in"] = jnp.zeros((F,))
        p["b_out"] = jnp.zeros((D,))
    return p


def apply_ffn(params: Dict, x: jnp.ndarray, cfg: ArchConfig, rt: Runtime) -> jnp.ndarray:
    qc = rt.quant_cfg(cfg)
    # tags key per-call-site tile tuning in kernels.autotune: the up/down
    # projections are the serving hot path and tune independently
    h = qdense(params["w_in"], x, qc, params.get("b_in"), tag="ffn.w_in")
    if cfg.ffn_type == "swiglu":
        g = qdense(params["w_gate"], x, qc, tag="ffn.w_gate")
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    h = shard(h, "act_btf")
    y = qdense(params["w_out"], h, qc, params.get("b_out"), tag="ffn.w_out")
    return shard(y, "act_btd")
