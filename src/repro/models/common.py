"""Shared model components: norms, rotary embeddings (RoPE / M-RoPE),
initializers and the activation-sharding helper."""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * w.astype(jnp.float32)).astype(dt)


def normal_init(key, shape, fan_in: Optional[int] = None, dtype=jnp.float32):
    fan = fan_in if fan_in is not None else shape[0]
    return jax.random.normal(key, shape, dtype) * (1.0 / math.sqrt(max(fan, 1)))


# ------------------------------------------------------------------- RoPE --
def rope_freqs(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(
    x: jnp.ndarray,                 # [B, S, H, hd]
    positions: jnp.ndarray,         # [B, S] int32
    theta: float,
) -> jnp.ndarray:
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                              # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs     # [B, S, hd/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jnp.ndarray,                 # [B, S, H, hd]
    positions: jnp.ndarray,         # [3, B, S] int32 (t, h, w streams)
    theta: float,
    sections: Tuple[int, int, int],
) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE: rotary frequency slots split into three
    sections driven by separate (temporal, height, width) position ids."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                              # [hd/2]
    assert sum(sections) == hd // 2, (sections, hd)
    ang_parts = []
    start = 0
    for s, sec in enumerate(sections):
        f = freqs[start:start + sec]
        ang_parts.append(positions[s][..., None].astype(jnp.float32) * f)
        start += sec
    ang = jnp.concatenate(ang_parts, axis=-1)                  # [B, S, hd/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos_embed(positions: jnp.ndarray, d_model: int) -> jnp.ndarray:
    """Non-learned absolute positional embedding (musicgen-style)."""
    half = d_model // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs     # [B, S, half]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
