"""Model zoo: block library + decoder LM assembly for all assigned archs."""
from .transformer import (  # noqa: F401
    decode_step,
    forward,
    init_caches,
    init_model,
    lm_loss,
    prefill,
)
