"""Model zoo: block library + decoder LM assembly for all assigned archs.

KV-cache interface: `init_caches` builds the contiguous (ring-buffer)
layout; the paged layout used by the serving engine is built by
`repro.serving.kv_pages.init_paged_caches` and consumed by the same
attention code (dispatch on the `"tbl"` block-table key in the cache dict).
"""
from .attention import (  # noqa: F401
    dequantize_kv,
    init_attn_cache,
    quantize_kv,
)
from .transformer import (  # noqa: F401
    decode_step,
    forward,
    init_caches,
    init_model,
    lm_loss,
    prefill,
)
