"""Mamba-2 (SSD — state-space duality) block, chunked and scan-free.

The inter-chunk recurrence uses `lax.associative_scan` (log-depth, fully
materialized in HLO) instead of a sequential `lax.scan`, so the dry-run
cost analysis sees every FLOP and the temporal mixer contains no while
loops (see EXPERIMENTS.md §Roofline methodology).

Projections (`in_proj`, `out_proj`) go through the paper's quantized linear;
the recurrent state itself stays fp32 (DESIGN.md §4 applicability note).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, Runtime
from repro.core.qlinear import qdense
from repro.core.quant_plan import join_site
from repro.distributed.sharding import shard
from .common import normal_init, rms_norm


def conv_dim(cfg: ArchConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state


def init_mamba(key, cfg: ArchConfig) -> Dict:
    D, di, N, H, G = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                      cfg.ssm_heads, cfg.ssm_groups)
    cd = conv_dim(cfg)
    ks = jax.random.split(key, 4)
    return {
        "in_proj": normal_init(ks[0], (D, 2 * di + 2 * G * N + H)),
        "conv_w": normal_init(ks[1], (cfg.ssm_conv, cd), fan_in=cfg.ssm_conv),
        "conv_b": jnp.zeros((cd,)),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)),
        "D": jnp.ones((H,)),
        "dt_bias": jnp.log(jnp.expm1(jnp.linspace(1e-3, 1e-1, H))),
        "norm_w": jnp.ones((di,)),
        "out_proj": normal_init(ks[2], (di, D), fan_in=di),
    }


def init_mamba_cache(cfg: ArchConfig, batch: int) -> Dict:
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim(cfg)), jnp.float32),
        "ssd": jnp.zeros(
            (batch, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state), jnp.float32
        ),
    }


def _causal_conv(xBC, w, b, conv_state=None):
    """Depthwise causal conv, width K, via K shifted adds (loop-free).
    xBC [B, S, C]; w [K, C]; conv_state [B, K-1, C] or None."""
    K = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xBC.shape[0], K - 1, xBC.shape[2]), xBC.dtype)
    else:
        pad = conv_state.astype(xBC.dtype)
    full = jnp.concatenate([pad, xBC], axis=1)                  # [B, S+K-1, C]
    S = xBC.shape[1]
    y = sum(full[:, k:k + S] * w[k][None, None, :] for k in range(K))
    new_state = full[:, full.shape[1] - (K - 1):]
    return y + b[None, None, :], new_state.astype(jnp.float32)


def apply_mamba(
    params: Dict,
    x: jnp.ndarray,                   # [B, S, D]
    cfg: ArchConfig,
    rt: Runtime,
    cache: Optional[Dict] = None,
    update_cache: bool = False,
    site: str = "mamba",
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    B, S, D = x.shape
    di, N, H, P_, G = (cfg.d_inner, cfg.ssm_state, cfg.ssm_heads,
                       cfg.ssm_headdim, cfg.ssm_groups)
    s_in = join_site(site, "in_proj")

    proj = qdense(params["in_proj"], x, rt.quant_cfg(cfg, s_in), tag=s_in)
    z = proj[..., :di]
    xBC = proj[..., di:di + conv_dim(cfg)]
    dt = proj[..., di + conv_dim(cfg):]
    xBC = shard(xBC, "act_btf")

    conv_state = cache["conv"] if cache is not None else None
    xBC, new_conv = _causal_conv(xBC, params["conv_w"], params["conv_b"],
                                 conv_state)
    xBC = jax.nn.silu(xBC)

    xs = xBC[..., :di].reshape(B, S, H, P_)
    Bm = xBC[..., di:di + G * N].reshape(B, S, G, N)
    Cm = xBC[..., di + G * N:].reshape(B, S, G, N)
    rep = H // G
    Bm = jnp.repeat(Bm, rep, axis=2)                            # [B, S, H, N]
    Cm = jnp.repeat(Cm, rep, axis=2)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B,S,H]
    A = -jnp.exp(params["A_log"])                               # [H]
    dA = dt * A                                                 # [B,S,H] <= 0

    if cache is not None and S == 1:
        # ---- decode: one recurrence step -------------------------------
        h = cache["ssd"]                                        # [B,H,P,N] f32
        dBx = jnp.einsum(
            "bh,bhn,bhp->bhpn",
            dt[:, 0], Bm[:, 0].astype(jnp.float32), xs[:, 0].astype(jnp.float32),
        )
        h = jnp.exp(dA[:, 0])[:, :, None, None] * h + dBx
        y = jnp.einsum("bhn,bhpn->bhp", Cm[:, 0].astype(jnp.float32), h)
        y = y + params["D"][None, :, None] * xs[:, 0].astype(jnp.float32)
        y = y[:, None].astype(x.dtype)                          # [B,1,H,P]
        new_cache = {"conv": new_conv, "ssd": h}
    else:
        # ---- chunked SSD ------------------------------------------------
        Q = min(cfg.ssm_chunk, S)
        pad = (-S) % Q
        if pad:
            xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
            Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
            Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Sp = S + pad
        nc = Sp // Q
        shp = lambda t, tail: t.reshape((B, nc, Q) + tail)
        xs_c = shp(xs, (H, P_)).astype(jnp.float32)
        B_c = shp(Bm, (H, N)).astype(jnp.float32)
        C_c = shp(Cm, (H, N)).astype(jnp.float32)
        dA_c = shp(dA, (H,))
        dt_c = shp(dt, (H,))

        l = jnp.cumsum(dA_c, axis=2)                            # [B,nc,Q,H]
        l_last = l[:, :, -1:, :]
        xdt = xs_c * dt_c[..., None]

        # intra-chunk (quadratic within chunk, masked causal).  Mask BEFORE
        # exp: the j>i region has l_i - l_j >> 0 and exp overflows to inf
        # (inf * 0 = NaN) if masked after.
        diff = l[:, :, :, None] - l[:, :, None, :, :]            # [B,nc,Q,Q,H]
        tri = jnp.tril(jnp.ones((Q, Q), jnp.bool_))[None, None, :, :, None]
        decay = jnp.exp(jnp.where(tri, diff, -jnp.inf))
        scores = jnp.einsum("bcqhn,bckhn->bcqkh", C_c, B_c) * decay
        y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", scores, xdt)

        # chunk summaries + inter-chunk associative scan
        w = jnp.exp(l_last - l)                                 # [B,nc,Q,H]
        S_c = jnp.einsum("bcqhn,bcqhp->bchpn", B_c * w[..., None], xdt)
        d_c = jnp.exp(l_last[:, :, 0, :])                       # [B,nc,H]

        def combine(a, b):
            da, sa = a
            db, sb = b
            return da * db, sa * db[..., None, None] + sb

        dcum, scum = jax.lax.associative_scan(combine, (d_c, S_c), axis=1)
        h0 = (cache["ssd"] if cache is not None
              else jnp.zeros((B, H, P_, N), jnp.float32))
        h_after = scum + h0[:, None] * dcum[..., None, None]
        h_before = jnp.concatenate([h0[:, None], h_after[:, :-1]], axis=1)

        y_inter = jnp.einsum(
            "bcqhn,bchpn->bcqhp", C_c * jnp.exp(l)[..., None], h_before
        )
        y = y_intra + y_inter + params["D"][None, None, None, :, None] * xs_c
        y = y.reshape(B, Sp, H, P_)[:, :S].astype(x.dtype)
        new_cache = None
        if update_cache:
            new_cache = {"conv": new_conv, "ssd": h_after[:, -1]}

    y = y.reshape(B, S, di)
    y = rms_norm(y * jax.nn.silu(z), params["norm_w"], cfg.norm_eps)
    s_out = join_site(site, "out_proj")
    out = qdense(params["out_proj"], y, rt.quant_cfg(cfg, s_out), tag=s_out)
    return shard(out, "act_btd"), new_cache
