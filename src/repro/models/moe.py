"""Mixture-of-Experts block with expert parallelism.

Dispatch is sort-based (argsort by expert id -> capacity-bounded per-expert
buffers -> batched expert GEMMs -> scatter-add combine).  Under a mesh
context the block runs inside `shard_map`: tokens stay sharded on the data
axis (replicated across `model`), experts are sharded on the `model` axis
(E/tp experts per device), each device computes only its experts'
contributions, and a single `psum` over `model` combines them — the same
per-layer collective volume as a Megatron FFN, with no all-to-all needed
because activations are TP-replicated between blocks.

Every expert projection uses the paper's int4 technique via fake-quant
(expert weights quantize per-output-channel exactly like dense FFNs).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, Runtime
from repro.core.quant import fake_quant
from repro.core.quant_plan import join_site
from repro.distributed.sharding import current_mesh, dp_axes, shard_map
from .common import normal_init


def init_moe(key, cfg: ArchConfig) -> Dict:
    D, E, F = cfg.d_model, cfg.n_experts, cfg.d_ff_expert or cfg.d_ff
    ks = jax.random.split(key, 4)
    p = {
        "router": {"w": normal_init(ks[0], (D, E))},
        "experts": {
            "w_in": normal_init(ks[1], (E, D, F)),
            "w_out": normal_init(ks[2], (E, F, D), fan_in=F),
        },
    }
    if cfg.ffn_type == "swiglu":
        p["experts"]["w_gate"] = normal_init(ks[3], (E, D, F))
    return p


def _capacity(n_tokens: int, cfg: ArchConfig) -> int:
    c = int(cfg.capacity_factor * n_tokens * cfg.top_k / cfg.n_experts) + 1
    return max(8, -(-c // 8) * 8)


def _expert_ffn(buf, experts, cfg: ArchConfig, rt: Runtime, site: str = "moe"):
    """buf [El, C, D] -> [El, C, D] through the (quantized) expert MLPs.
    All expert weights share one plan site (`<site>.experts`): they run as a
    batched einsum, so per-expert backends are not addressable."""
    qc = rt.quant_cfg(cfg, join_site(site, "experts"))

    def dense(w):
        if isinstance(w, dict):                # packed int4 serving weights
            from repro.core.quant import unpack_int4

            q = unpack_int4(w["packed"], axis=-1)
            return (q.astype(jnp.float32) * w["scale"]).astype(buf.dtype)
        if qc.backend == "fake_quant":
            # per-output-channel fake-quant along each expert's reduction dim
            w = fake_quant(w, axis=1, bits=qc.w_bits)
        return w.astype(buf.dtype)

    h = jnp.einsum("ecd,edf->ecf", buf, dense(experts["w_in"]))
    if "w_gate" in experts:
        g = jnp.einsum("ecd,edf->ecf", buf, dense(experts["w_gate"]))
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h, dense(experts["w_out"]))


def _moe_shard(xf, router_w, experts, *, e_start, n_local, cfg, rt, axis=None,
               site="moe"):
    """Core dispatch/compute/combine for `n_local` experts starting at
    `e_start`. xf [T, D]. Returns (partial y [T, D], per-token aux [T])."""
    T, D = xf.shape
    E, k = cfg.n_experts, cfg.top_k
    C = _capacity(T, cfg)

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)                    # [T, E]
    gate, idx = jax.lax.top_k(probs, k)                        # [T, k]
    if k > 1:
        gate = gate / (jnp.sum(gate, axis=-1, keepdims=True) + 1e-9)

    flat_e = idx.reshape(-1)                                   # [T*k]
    flat_g = gate.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=E)                    # [E]
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(T * k) - starts[sorted_e]
    tok = order // k

    local = (sorted_e >= e_start) & (sorted_e < e_start + n_local) & (rank < C)
    slot_e = jnp.clip(sorted_e - e_start, 0, n_local - 1)
    slot_c = jnp.clip(rank, 0, C - 1)
    w = jnp.where(local, 1.0, 0.0).astype(xf.dtype)

    buf = jnp.zeros((n_local, C, D), xf.dtype)
    buf = buf.at[slot_e, slot_c].add(w[:, None] * xf[tok])

    out_buf = _expert_ffn(buf, experts, cfg, rt, site=site)    # [El, C, D]

    gathered = out_buf[slot_e, slot_c]                         # [T*k, D]
    contrib = gathered * (jnp.where(local, flat_g[order], 0.0)).astype(xf.dtype)[:, None]
    y = jnp.zeros((T, D), xf.dtype).at[tok].add(contrib)

    # Switch-style load-balance aux: E * sum_e( frac_tokens_e * mean_prob_e )
    frac = counts.astype(jnp.float32) / (T * k)
    mean_p = jnp.mean(probs, axis=0)
    aux = cfg.n_experts * jnp.sum(frac * mean_p)
    if axis is not None:
        y = jax.lax.psum(y, axis)
    return y, jnp.full((T,), aux, jnp.float32)


def apply_moe(
    params: Dict, x: jnp.ndarray, cfg: ArchConfig, rt: Runtime,
    site: str = "moe",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x [B, S, D] -> (y [B, S, D], aux scalar)."""
    B, S, D = x.shape
    xf = x.reshape(B * S, D)
    mesh = current_mesh()
    dpa = dp_axes()
    dp_size = 1
    if mesh is not None:
        for a in dpa:
            dp_size *= mesh.shape[a]
    use_shard_map = (
        mesh is not None
        and cfg.n_experts % mesh.shape["model"] == 0
        and (B * S) % dp_size == 0
        and B % dp_size == 0          # xf keeps dim-0 sharding after reshape
    )
    if use_shard_map:
        tp = mesh.shape["model"]
        dp = mesh.shape["data"]
        n_local = cfg.n_experts // tp
        dspec = dpa if len(dpa) > 1 else dpa[0]

        # Per-leaf spec + FSDP-gather axis.  Expert weights are E-sharded on
        # `model` and (when divisible) sharded on `data` along the gatherable
        # axis (F for w_in/w_gate and their scales; F for w_out.packed; the
        # tiny w_out.scale [E,1,D] stays replicated).
        def leaf_plan(name, leaf):
            ax = 1 if name == "w_out" else 2
            if leaf.ndim == 3 and leaf.shape[ax] % dp == 0 and leaf.shape[ax] > 1:
                spec = [None, None, None]
                spec[0] = "model"
                spec[ax] = "data"
                return P(*spec), ax
            return P("model", None, None), None

        especs, gather_ax = {}, {}
        for k, v in params["experts"].items():
            if isinstance(v, dict):
                especs[k], gather_ax[k] = {}, {}
                for kk, leaf in v.items():
                    especs[k][kk], gather_ax[k][kk] = leaf_plan(k, leaf)
            else:
                especs[k], gather_ax[k] = leaf_plan(k, v)

        def body(xf_l, rw, experts_l):
            # FSDP-style gather of data-sharded expert weights; the backward
            # of all_gather is the matching reduce-scatter.  Float master
            # weights are cast to bf16 *before* the gather (mixed-precision
            # FSDP: halves gather + grad reduce-scatter bytes; the f32
            # master/moments stay sharded at rest).
            def gather(w, ax):
                if isinstance(w, dict):
                    return {kk: gather(ww, ax[kk]) for kk, ww in w.items()}
                if (rt.compute_dtype == "bfloat16" and w.dtype == jnp.float32
                        and w.ndim == 3 and w.shape[-2] > 1):
                    w = w.astype(jnp.bfloat16)   # not quant scales [E,1,*]
                if ax is None:
                    return w
                return jax.lax.all_gather(w, "data", axis=ax, tiled=True)

            experts_l = {k: gather(w, gather_ax[k])
                         for k, w in experts_l.items()}
            e_start = jax.lax.axis_index("model") * n_local
            return _moe_shard(
                xf_l, rw, experts_l,
                e_start=e_start, n_local=n_local, cfg=cfg, rt=rt, axis="model",
                site=site,
            )

        y, aux_t = shard_map(
            body,
            mesh=mesh,
            in_specs=(P(dspec, None), P(None, None), especs),
            out_specs=(P(dspec, None), P(dspec)),
            check=False,
        )(xf, params["router"]["w"], params["experts"])
    else:
        y, aux_t = _moe_shard(
            xf, params["router"]["w"], params["experts"],
            e_start=0, n_local=cfg.n_experts, cfg=cfg, rt=rt, site=site,
        )
    return y.reshape(B, S, D), jnp.mean(aux_t)
