"""AdamW with decoupled weight decay and global-norm clipping (pure pytrees,
fp32 states).  States mirror the parameter tree, so the ZeRO-style sharding
rules in `distributed.sharding` apply to them unchanged."""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda p: jax.tree.map(  # noqa: E731
        lambda x: jnp.zeros(x.shape, jnp.float32), p
    )
    return {"mu": zeros(params), "nu": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def adamw_update(
    params,
    grads,
    opt_state: Dict[str, Any],
    lr: jnp.ndarray,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    max_grad_norm: float = 1.0,
) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    grads, gn = clip_by_global_norm(grads, max_grad_norm)
    step = opt_state["step"] + 1
    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        update = (mu / b1c) / (jnp.sqrt(nu / b2c) + eps)
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (update + weight_decay * p32)
        return p32.astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(opt_state["mu"])
    flat_nu = jax.tree.leaves(opt_state["nu"])
    new_p, new_mu, new_nu = [], [], []
    for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu):
        a, b, c = upd(p, g, mu, nu)
        new_p.append(a)
        new_mu.append(b)
        new_nu.append(c)
    return (
        jax.tree.unflatten(tdef, new_p),
        {"mu": jax.tree.unflatten(tdef, new_mu),
         "nu": jax.tree.unflatten(tdef, new_nu),
         "step": step},
        {"grad_norm": gn},
    )
