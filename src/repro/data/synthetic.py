"""Deterministic, shard-aware synthetic token pipeline.

Real deployments plug a tokenized corpus in here; the interface is the part
the framework depends on:

  * deterministic by (seed, step, shard) -> restart/elastic-safe: after a
    preemption the stream resumes exactly, even on a different host count;
  * per-host sharding by `(process_index, process_count)` so each host
    materializes only its slice of the global batch;
  * background prefetch with a bounded queue (straggler smoothing).

Token stream: a mixture of Zipf-distributed unigrams with short Markov
back-references, which gives a non-trivial learnable distribution (loss
drops well below uniform) without external data.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np


@dataclasses.dataclass
class SyntheticLMDataset:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    shard_index: int = 0
    shard_count: int = 1
    zipf_a: float = 1.2

    def __post_init__(self):
        assert self.global_batch % self.shard_count == 0
        self.local_batch = self.global_batch // self.shard_count
        # fixed unigram table (deterministic across hosts)
        rng = np.random.default_rng(self.seed)
        ranks = np.arange(1, self.vocab + 1, dtype=np.float64)
        p = ranks ** (-self.zipf_a)
        self._p = p / p.sum()
        self._perm = rng.permutation(self.vocab)

    def batch(self, step: int) -> np.ndarray:
        """[local_batch, seq_len+1] int32 tokens for `step` (deterministic)."""
        out = np.empty((self.local_batch, self.seq_len + 1), np.int32)
        for i in range(self.local_batch):
            row = self.shard_index * self.local_batch + i
            rng = np.random.default_rng(
                (self.seed, step, row)
            )
            toks = self._perm[
                rng.choice(self.vocab, size=self.seq_len + 1, p=self._p)
            ].astype(np.int32)
            # Markov back-references: 25% of positions copy t-δ (learnable)
            back = rng.random(self.seq_len + 1) < 0.25
            delta = rng.integers(1, 8, size=self.seq_len + 1)
            for t in np.nonzero(back)[0]:
                if t - delta[t] >= 0:
                    toks[t] = toks[t - delta[t]]
            out[i] = toks
        return out


def make_batch_iterator(
    ds: SyntheticLMDataset,
    start_step: int = 0,
    prefetch: int = 2,
) -> Iterator[np.ndarray]:
    """Background-prefetching iterator starting at `start_step` (resumable)."""
    q: "queue.Queue" = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def producer():
        step = start_step
        while not stop.is_set():
            try:
                q.put(ds.batch(step), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    th = threading.Thread(target=producer, daemon=True)
    th.start()

    class _Iter:
        def __iter__(self):
            return self

        def __next__(self):
            return q.get()

        def close(self):
            stop.set()

    return _Iter()
