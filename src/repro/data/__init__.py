"""Data pipeline."""
from .synthetic import SyntheticLMDataset, make_batch_iterator  # noqa: F401
