"""musicgen-large [audio] — decoder-only transformer over EnCodec tokens.

48L d_model=2048 32H (kv=32 => full MHA) d_ff=8192 vocab=2048.
Source: arXiv:2306.05284 (MusicGen); hf:facebook/musicgen-large. [hf tier]
Modality frontend (EnCodec + delay-pattern interleaving + text conditioning)
is a STUB per the assignment: input_specs() provides token ids directly.
Positional encoding: non-learned sinusoidal (rope="none").
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="dense",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=2048,
    ffn_type="gelu",
    rope="none",
    source="arXiv:2306.05284; hf:facebook/musicgen-large [hf]",
    notes="audio backbone; EnCodec frontend stubbed (DESIGN.md §4)",
)
