"""qwen3-4b [dense] — GQA + qk_norm.

36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936.
Source: hf:Qwen/Qwen3-4B (per-assignment citation hf:Qwen/Qwen3-8B). [hf tier]
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-4b",
    family="dense",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab=151936,
    qk_norm=True,
    rope="rope",
    rope_theta=1000000.0,
    source="hf:Qwen/Qwen3-8B [hf]",
)
