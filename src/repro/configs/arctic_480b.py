"""arctic-480b [moe] — 128 experts top-2 in parallel with a dense residual MLP.

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2.
Source: hf:Snowflake/snowflake-arctic-base. [hf tier]
Arctic's dense-MoE hybrid: every layer = attention + (dense FFN || MoE FFN).
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab=32000,
    rope="rope",
    n_experts=128,
    top_k=2,
    d_ff_expert=4864,
    moe_dense_ff=4864,
    source="hf:Snowflake/snowflake-arctic-base [hf]",
    notes="dense-residual + top-2 MoE per layer",
)
