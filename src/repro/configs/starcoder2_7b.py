"""starcoder2-7b [dense] — GQA kv=4, RoPE, GELU MLP with biases.

32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152.
Source: arXiv:2402.19173; hf:bigcode/starcoder2-7b. [hf tier]
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab=49152,
    ffn_type="gelu",
    qkv_bias=True,
    mlp_bias=True,
    rope="rope",
    rope_theta=1000000.0,
    source="arXiv:2402.19173; hf:bigcode/starcoder2-7b [hf]",
)
