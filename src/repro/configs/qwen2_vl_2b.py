"""qwen2-vl-2b [vlm] — M-RoPE, GQA kv=2, tied embeddings.

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.
Source: arXiv:2409.12191; hf:Qwen/Qwen2-VL-2B. [hf tier]
Vision frontend (dynamic-resolution ViT producing patch embeddings) is a
STUB per the assignment: input_specs() provides token ids + 3-stream M-RoPE
position ids (temporal/height/width); for pure text the three streams
coincide.  head_dim=128 => mrope_sections (16, 24, 24) over 64 freq slots.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab=151936,
    qkv_bias=True,
    tie_embeddings=True,
    rope="mrope",
    rope_theta=1000000.0,
    mrope_sections=(16, 24, 24),
    source="arXiv:2409.12191; hf:Qwen/Qwen2-VL-2B [hf]",
    notes="vision frontend stubbed (DESIGN.md §4)",
)
