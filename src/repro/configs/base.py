"""Architecture + runtime configuration dataclasses.

`ArchConfig` is the *identity* of a model (frozen, hashable, from public
literature); `Runtime` holds execution knobs (scan vs unroll, attention
implementation, remat, quant backend) that never change the math, only the
compiled schedule — they are the §Perf hillclimbing levers.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

from repro.core.qlinear import QuantConfig


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 => d_model // n_heads
    # attention flavour
    qk_norm: bool = False
    qkv_bias: bool = False
    mlp_bias: bool = False
    ffn_type: str = "swiglu"      # swiglu | gelu
    rope: str = "rope"            # rope | mrope | none (sinusoidal abs)
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    moe_dense_ff: int = 0         # parallel dense-residual FFN (arctic)
    shared_expert: bool = False   # always-on expert (llama4)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_groups: int = 1
    ssm_chunk: int = 256
    # hybrid (recurrentgemma): layer pattern repeated + tail
    pattern: Tuple[str, ...] = ("A",)   # per-layer mixer types in one repeat
    tail: Tuple[str, ...] = ()          # trailing layers after the repeats
    local_window: int = 0               # >0: sliding-window attention
    lru_width: int = 0
    # misc
    norm_eps: float = 1e-6
    quant: QuantConfig = QuantConfig(backend="fake_quant")
    # optional per-arch mixed-precision plan (preset name | json path |
    # inline rules — see core.quant_plan); None => uniform `quant`
    quant_plan: Optional[str] = None
    notes: str = ""
    source: str = ""

    # ------------------------------------------------------------------ api
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_repeats(self) -> int:
        assert (self.n_layers - len(self.tail)) % len(self.pattern) == 0, self.name
        return (self.n_layers - len(self.tail)) // len(self.pattern)

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up so the model axis (<=16) always divides it."""
        return -(-self.vocab // 128) * 128

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True iff decode cost/cache is O(1)-or-O(window) in context length,
        which is what long_500k requires (SSM state or local-window attn)."""
        return self.family == "ssm" or (
            self.family == "hybrid" and self.local_window > 0
        )

    def reduced(self, **overrides) -> "ArchConfig":
        """A small same-family config for CPU smoke tests."""
        base = dict(
            n_layers=len(self.pattern) + len(self.tail),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            head_dim=16,
            n_experts=8 if self.n_experts else 0,
            d_ff_expert=64 if self.d_ff_expert else 0,
            moe_dense_ff=64 if self.moe_dense_ff else 0,
            ssm_state=32 if self.ssm_state else 0,
            ssm_headdim=16,
            ssm_chunk=16,
            local_window=16 if self.local_window else 0,
            lru_width=64 if self.lru_width else 0,
            mrope_sections=(2, 3, 3),   # sums to reduced head_dim/2
        )
        base.update(overrides)
        return dataclasses.replace(self, **base)


@dataclasses.dataclass(frozen=True)
class Shape:
    """An assigned input-shape cell."""

    name: str
    kind: str        # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": Shape("train_4k", "train", 4096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32768, 128),
    "long_500k": Shape("long_500k", "decode", 524288, 1),
}


def runnable(arch: ArchConfig, shape: Shape) -> bool:
    """long_500k needs sub-quadratic attention (see DESIGN.md §4)."""
    if shape.name == "long_500k":
        return arch.sub_quadratic
    return True


@dataclasses.dataclass(frozen=True)
class Runtime:
    """Execution knobs — §Perf levers; never change model math."""

    scan_layers: bool = True
    attn_impl: str = "chunked"      # chunked | full | flash (tiled online-
                                    # softmax kernel, kernels.paged_attention)
    attn_chunk_q: int = 512
    # paged-KV decode attention: "fused" consumes pages in place through the
    # kernels.ops.paged_decode_attention dispatch (Pallas on TPU, XLA twin
    # elsewhere); "gather" is the paged_read-then-attend baseline the
    # bit-exactness harness compares against.
    paged_attn: str = "fused"
    loss_chunk: int = 4096          # 0 = unchunked
    remat: str = "dots"             # none | dots | full
    # DEPRECATED: uniform backend-string override (kept working — it maps to
    # a uniform plan).  Prefer `quant_plan`, which carries the full per-site
    # QuantConfig instead of losing everything but the backend string.
    quant_backend: Optional[str] = None
    # mixed-precision plan spec: preset name | json path | inline
    # "pattern=backend[;...]" rules (core.quant_plan).  Takes precedence
    # over quant_backend and ArchConfig.quant/quant_plan.
    quant_plan: Optional[str] = None
    cache_dtype: str = "bfloat16"   # KV-cache dtype: bfloat16 | int8 (§Perf)
    compute_dtype: str = "bfloat16"
    aligned_decode: bool = True     # batch rows share positions: DUS cache
                                    # writes instead of scatter (§Perf)
    # Paged prefill attends over the gathered page pool instead of the
    # in-flight K/V: the tail-prefill step for prefix-cache hits (the query
    # covers only the uncached suffix; cached prefix K/V live in shared
    # pages).  Static knob — the engine jits one prefill per value.
    prefill_over_cache: bool = False

    def quant_cfg(self, arch: ArchConfig, site: str = "") -> QuantConfig:
        """Per-site QuantConfig under the active plan.  `site` is the
        hierarchical call-site name (e.g. "block[3].attn.qkv"); "" resolves
        the plan default — exactly the old uniform behavior."""
        from repro.core.quant_plan import active_plan

        return active_plan(arch, self).resolve(site)


COST_PROBE = Runtime(scan_layers=False, attn_impl="full", loss_chunk=0, remat="none")


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Continuous-batching serving knobs (see repro.serving).

    `layout="paged"` allocates KV storage as fixed-size pages from a shared
    pool with per-sequence block tables; `"contiguous"` preallocates one
    `max_ctx`-long cache row per batch slot (static-slot baseline).  Bucketing
    bounds the number of distinct jit signatures: decode batches are padded
    up to the nearest bucket, prompts to the nearest power-of-two length.

    `prefix_cache` (paged layout only) content-addresses full KV pages by
    chained prefix hash: admission reuses cached pages for the longest
    page-aligned prompt/resume prefix (refcount-shared, never rewritten) and
    prefill computes only the uncached tail.  `prefix_lru` keeps freed
    registered pages in the index (refcount 0, evicted LRU only when the
    free list runs dry); off, released pages forget their contents at once.
    """

    layout: str = "paged"           # paged | contiguous
    max_batch: int = 8              # concurrent decode slots
    page_size: int = 16             # tokens per KV page
    num_pages: int = 128            # shared pool size (paged layout)
    max_ctx: int = 256              # max prompt+generation length per request
    decode_buckets: Tuple[int, ...] = ()   # () => powers of two up to max_batch
    prefix_cache: bool = True       # shared-prefix KV page reuse (paged only)
    prefix_lru: bool = True         # keep refcount-0 pages cached until dry
    # "ragged" packs every live request's tokens — chunked-prefill slices
    # and decode tokens alike — into one flat [1, token_budget] buffer and
    # runs ONE jit per step (kernels.ragged_attention); "bucketed" is the
    # classic separate prefill/decode jits over padded bucket shapes.
    step: str = "bucketed"          # bucketed | ragged (paged layout only)
    # ragged step's padded token capacity per step; 0 = auto.  Decode
    # tokens (one per running request) are packed first, prefill chunks
    # fill the remainder.  The engine grows it (next power of two, one
    # fresh compile) if running requests ever exceed it.
    token_budget: int = 0
    # ---- request-lifecycle hardening (see serving/chaos.py) -------------
    # bounded admission queue: submit() raises a typed ShedError once this
    # many requests wait (0 = unbounded, the legacy behavior).  Load
    # shedding instead of unbounded queue growth under overload.
    max_queue: int = 0
    # watchdog deadline around each engine step (distributed.fault_tolerance
    # Watchdog): a step exceeding it bumps
    # serving_step_deadline_exceeded_total, and raises StepDeadlineExceeded
    # when strict.  0 = off.
    step_deadline_s: float = 0.0
    step_deadline_strict: bool = False

    def __post_init__(self):
        assert self.layout in ("paged", "contiguous"), self.layout
        assert self.step in ("bucketed", "ragged"), self.step
        assert self.step == "bucketed" or self.layout == "paged", \
            "the ragged step packs tokens through block tables (paged only)"
        assert self.max_ctx % self.page_size == 0, \
            f"max_ctx {self.max_ctx} must be a multiple of page_size {self.page_size}"
        assert self.max_queue >= 0 and self.step_deadline_s >= 0.0

    @property
    def budget(self) -> int:
        """Effective ragged token budget: explicit (taken verbatim — may sit
        below max_batch, in which case the engine doubles it at runtime the
        step the decode set outgrows it: one fresh compile, never a
        steady-state recompile), else enough for every decode slot plus a
        healthy prefill chunk, power-of-two padded."""
        if self.token_budget:
            return self.token_budget
        return self.prompt_bucket(self.max_batch + 2 * self.page_size)

    @property
    def pages_per_seq(self) -> int:
        return self.max_ctx // self.page_size

    @property
    def buckets(self) -> Tuple[int, ...]:
        if self.decode_buckets:
            return tuple(sorted(set(self.decode_buckets) | {self.max_batch}))
        b, out = 1, []
        while b < self.max_batch:
            out.append(b)
            b *= 2
        return tuple(out) + (self.max_batch,)

    def decode_bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.max_batch

    @staticmethod
    def prompt_bucket(n: int) -> int:
        b = 8
        while b < n:
            b *= 2
        return b
