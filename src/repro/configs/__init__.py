"""Architecture registry: the 10 assigned configs + the paper's own
multiplier-array 'config'.  Each file documents its public source and
verification tier.  Select with ``--arch <id>``."""

from __future__ import annotations

from typing import Dict

from .base import (  # noqa: F401
    ArchConfig, COST_PROBE, Runtime, ServingConfig, SHAPES, Shape, runnable,
)

from .musicgen_large import CONFIG as _musicgen
from .mamba2_130m import CONFIG as _mamba2
from .qwen3_4b import CONFIG as _qwen3
from .internlm2_20b import CONFIG as _internlm2
from .starcoder2_7b import CONFIG as _starcoder2
from .qwen2_0_5b import CONFIG as _qwen2_05
from .llama4_maverick import CONFIG as _llama4
from .arctic_480b import CONFIG as _arctic
from .qwen2_vl_2b import CONFIG as _qwen2_vl
from .recurrentgemma_9b import CONFIG as _rgemma

REGISTRY: Dict[str, ArchConfig] = {
    c.name: c
    for c in [
        _musicgen, _mamba2, _qwen3, _internlm2, _starcoder2,
        _qwen2_05, _llama4, _arctic, _qwen2_vl, _rgemma,
    ]
}


def get_config(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch '{name}'; have {sorted(REGISTRY)}")
    return REGISTRY[name]


def all_archs():
    return sorted(REGISTRY)
