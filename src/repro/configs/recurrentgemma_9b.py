"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1:2 ratio.

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000.
Source: arXiv:2402.19427 (Griffin) / RecurrentGemma. [unverified tier]
Pattern (R, R, A) x 12 + (R, R) tail = 38 layers, 26 recurrent : 12 attention
(the paper's 2-recurrent-per-attention ratio).  Local window 2048 => decode
cache is O(window): sub-quadratic, runs long_500k.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    rope="rope",
    pattern=("R", "R", "A"),
    tail=("R", "R"),
    local_window=2048,
    lru_width=4096,
    ssm_conv=4,
    source="arXiv:2402.19427 [unverified]",
    notes="RG-LRU width 4096; MQA local attention window 2048",
)
