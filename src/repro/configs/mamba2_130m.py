"""mamba2-130m [ssm] — attention-free SSD (state-space duality).

24L d_model=768 d_ff=0 vocab=50280 ssm_state=128.
Source: arXiv:2405.21060 (Mamba-2). [unverified tier]
d_inner=2*768=1536, headdim=64 => 24 SSD heads, 1 group. Pure mamba blocks
(no separate FFN; d_ff=0). Sub-quadratic => runs long_500k.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=12,            # unused (attention-free); kept for interface
    n_kv_heads=12,
    d_ff=0,
    vocab=50280,
    rope="none",
    tie_embeddings=True,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_groups=1,
    ssm_chunk=128,
    pattern=("M",),
    source="arXiv:2405.21060 [unverified]",
    notes="vocab padded 50280->50304 for TP divisibility (GPT-NeoX-style)",
)
