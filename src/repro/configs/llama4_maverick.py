"""llama4-maverick-400b-a17b [moe] — 128 experts top-1 + shared expert.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1.
Source: hf:meta-llama/Llama-4-Scout-17B-16E (assignment citation).
[unverified tier] — config used exactly as assigned; early-fusion multimodal
frontend is out of scope (text backbone only, per assignment).
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202048,
    rope="rope",
    rope_theta=500000.0,
    n_experts=128,
    top_k=1,
    d_ff_expert=8192,
    shared_expert=True,
    source="hf:meta-llama/Llama-4-Scout-17B-16E [unverified]",
    notes="top-1 routing + always-on shared expert (early-fusion stubbed)",
)
